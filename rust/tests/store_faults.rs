//! Integration tests for the crash-safe run store driven through the
//! sweep engine — the contracts `caba sweep --store` and `caba serve`
//! rely on:
//!
//! * a cold matrix against a fresh store and a warm re-run from a fresh
//!   in-memory cache over the same directory are **bit-identical**;
//! * run-control knobs (telemetry, trace recording) never fragment store
//!   keys — a telemetry-carrying job warms from a plain job's entry;
//! * injected torn writes quarantine on read and the point recomputes and
//!   heals — never wrong data, never a crash.

use caba::sim::designs::Design;
use caba::stats::SimStats;
use caba::store::{FaultPlan, RunStore};
use caba::sweep::{RunCache, SweepEngine, SweepJob};
use caba::workload::apps;
use caba::SimConfig;
use std::sync::Arc;

fn small_cfg() -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.n_sms = 2;
    cfg.max_cycles = 150_000;
    cfg
}

fn matrix() -> Vec<SweepJob> {
    ["SLA", "PVC"]
        .into_iter()
        .flat_map(|name| {
            let app = apps::find(name).unwrap();
            [Design::base(), Design::caba(caba::compress::Algo::Bdi)]
                .into_iter()
                .map(move |d| SweepJob::new(app, d, small_cfg(), 0.01))
        })
        .collect()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("caba_store_faults_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn engine_over(dir: &std::path::Path) -> SweepEngine {
    let store = RunStore::open(dir).unwrap();
    SweepEngine::with_cache(2, Arc::new(RunCache::with_store(Arc::new(store))))
}

#[test]
fn cold_and_warm_runs_are_bit_identical_across_processes() {
    let dir = temp_dir("coldwarm");
    let jobs = matrix();

    // Cold pass: every point simulated, every point persisted.
    let cold_engine = engine_over(&dir);
    let cold: Vec<SimStats> = cold_engine.run(&jobs).unwrap();
    let c = cold_engine.cache().store_counters().unwrap();
    assert_eq!(c.puts, jobs.len() as u64, "every cold point must be persisted");
    assert_eq!(c.warm_hits, 0);
    assert_eq!(c.quarantined, 0);

    // Warm pass: a fresh in-memory cache over the same directory — the
    // moral equivalent of a process restart. No simulation, no new puts,
    // and the stats must round-trip bit-identically (the f64 included).
    let warm_engine = engine_over(&dir);
    let warm: Vec<SimStats> = warm_engine.run(&jobs).unwrap();
    let w = warm_engine.cache().store_counters().unwrap();
    assert_eq!(w.puts, 0, "warm run must not re-simulate");
    assert_eq!(w.warm_hits, jobs.len() as u64);
    assert_eq!(cold, warm, "store round-trip must be bit-identical");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn run_control_knobs_never_fragment_store_keys() {
    let dir = temp_dir("knobs");
    let app = apps::find("SLA").unwrap();
    let plain = SweepJob::new(app, Design::base(), small_cfg(), 0.01);
    let mut telem_cfg = small_cfg();
    telem_cfg.telemetry_window = 512;
    telem_cfg.trace_record = "/tmp/should_not_be_written.cabatrace".to_string();
    let knobbed = SweepJob::new(app, Design::base(), telem_cfg, 0.01);
    assert_eq!(plain.key(), knobbed.key(), "run-control knobs must be stripped from keys");

    let cold = engine_over(&dir);
    let a = cold.run(std::slice::from_ref(&plain)).unwrap();
    // A fresh cache over the same dir answers the knob-carrying job from
    // the plain job's entry — one file, one simulation, ever.
    let warm = engine_over(&dir);
    let b = warm.run(std::slice::from_ref(&knobbed)).unwrap();
    assert_eq!(a, b);
    assert_eq!(warm.cache().store_counters().unwrap().warm_hits, 1);
    assert_eq!(RunStore::open(&dir).unwrap().len(), 1);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_write_quarantines_then_recomputes_and_heals() {
    let dir = temp_dir("torn");
    let app = apps::find("SLA").unwrap();
    let job = SweepJob::new(app, Design::base(), small_cfg(), 0.01);

    // Cold run whose one store write is torn mid-entry (the injected
    // fault writes a truncated entry to the final path and reports
    // success, exactly like a crash between write and fsync).
    let fault = Arc::new(FaultPlan::parse("torn_write_at=0").unwrap());
    let store = RunStore::open(&dir).unwrap().with_fault(Arc::clone(&fault));
    let torn_engine = SweepEngine::with_cache(1, Arc::new(RunCache::with_store(Arc::new(store))));
    let reference = torn_engine.run(std::slice::from_ref(&job)).unwrap();
    assert_eq!(fault.injected(), 1, "the torn-write fault must have fired");

    // Restart: the truncated entry must quarantine on read — never
    // mis-parse — and the point recomputes to the same stats and heals
    // the store for the run after that.
    let second = engine_over(&dir);
    let recomputed = second.run(std::slice::from_ref(&job)).unwrap();
    let c = second.cache().store_counters().unwrap();
    assert_eq!(c.quarantined, 1, "torn entry must be quarantined, not parsed");
    assert_eq!(c.puts, 1, "recomputed point must be re-persisted");
    assert_eq!(reference, recomputed, "recovery must reproduce the same stats");

    let third = engine_over(&dir);
    assert_eq!(third.run(std::slice::from_ref(&job)).unwrap(), reference);
    let h = third.cache().store_counters().unwrap();
    assert_eq!((h.warm_hits, h.quarantined, h.puts), (1, 0, 0), "store must be healed");

    let _ = std::fs::remove_dir_all(&dir);
}
