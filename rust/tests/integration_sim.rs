//! End-to-end simulator integration: every app runs to completion under the
//! baseline, stall accounting is conserved, and the memory hierarchy
//! numbers are internally consistent.

use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::SimConfig;

fn small_cfg() -> SimConfig {
    let mut c = SimConfig::default();
    // Shrink the chip but keep the paper's compute:bandwidth balance.
    c.n_sms = 4;
    c.bw_scale = 4.0 / 15.0;
    c.max_cycles = 2_000_000;
    c
}

#[test]
fn all_27_apps_complete_under_base() {
    for app in apps::APPS {
        let stats = Simulator::new(small_cfg(), Design::base(), app, 0.01).run();
        assert!(stats.finished, "{} did not finish", app.name);
        assert!(stats.warp_insts > 0, "{}", app.name);
        // Issue-slot conservation: every scheduler slot of every cycle is
        // accounted as exactly one category (Fig. 2 must sum to 100%).
        assert_eq!(
            stats.issue.total(),
            stats.cycles * (small_cfg().n_sms * small_cfg().schedulers_per_sm) as u64,
            "{}: issue slots not conserved",
            app.name
        );
        // Cache identities.
        assert_eq!(stats.l1.accesses, stats.l1.hits + stats.l1.misses, "{}", app.name);
        assert_eq!(stats.l2.accesses, stats.l2.hits + stats.l2.misses, "{}", app.name);
        // Uncompressed baseline moves exactly 4 bursts per line.
        assert_eq!(stats.dram.compression_ratio(), 1.0, "{}", app.name);
    }
}

#[test]
fn memory_bound_apps_stall_on_memory() {
    // The paper's Fig. 2 claim: memory-bound apps spend most non-active
    // slots on memory-structural + data-dependence stalls.
    let app = apps::find("SLA").unwrap();
    let stats = Simulator::new(small_cfg(), Design::base(), app, 0.02).run();
    let (c, m, d, _i, a) = stats.issue.fractions();
    assert!(m + d > 0.5, "mem+data = {}", m + d);
    assert!(a < 0.5);
    assert!(c < 0.2);
}

#[test]
fn compute_bound_app_insensitive_to_bandwidth() {
    // Fig. 2 / §3: doubling bandwidth barely moves compute-bound apps.
    let app = apps::find("STO").unwrap();
    let base = Simulator::new(small_cfg(), Design::base(), app, 0.02).run();
    let mut cfg2 = small_cfg();
    cfg2.bw_scale *= 2.0;
    let doubled = Simulator::new(cfg2, Design::base(), app, 0.02).run();
    let speedup = base.cycles as f64 / doubled.cycles as f64;
    assert!(
        speedup < 1.10,
        "compute-bound app sped up {speedup}x from 2x bandwidth"
    );
}

#[test]
fn memory_bound_app_sensitive_to_bandwidth() {
    let app = apps::find("PVC").unwrap();
    let mut half = small_cfg();
    half.bw_scale *= 0.5;
    let halved = Simulator::new(half, Design::base(), app, 0.02).run();
    let base = Simulator::new(small_cfg(), Design::base(), app, 0.02).run();
    let slowdown = halved.cycles as f64 / base.cycles as f64;
    assert!(slowdown > 1.3, "halving BW only cost {slowdown}x");
}

#[test]
fn bandwidth_utilization_bounded_and_high_when_bound() {
    let app = apps::find("PVC").unwrap();
    let stats = Simulator::new(small_cfg(), Design::base(), app, 0.02).run();
    let util = stats
        .dram
        .bandwidth_utilization(stats.cycles, small_cfg().n_mcs);
    assert!(util > 0.5, "memory-bound app should saturate: {util}");
    assert!(util <= 1.0);
}

#[test]
fn occupancy_limits_respected() {
    let cfg = SimConfig::default();
    for app in apps::APPS {
        let occ = caba::workload::occupancy(app, &cfg, 0);
        assert!(occ.warps_per_sm <= cfg.max_warps_per_sm as u32, "{}", app.name);
        assert!(occ.ctas_per_sm <= cfg.max_ctas_per_sm as u32, "{}", app.name);
        assert!(
            occ.ctas_per_sm as usize * app.threads_per_cta as usize
                <= cfg.max_threads_per_sm,
            "{}",
            app.name
        );
        assert!(occ.regs_allocated <= cfg.regfile_per_sm as u32, "{}", app.name);
        assert!((0.0..=1.0).contains(&occ.unallocated_reg_frac), "{}", app.name);
    }
}

#[test]
fn md_cache_hit_rate_in_paper_range() {
    // §5.3.2: 8KB 4-way MD cache averages 85% (many apps > 99%).
    let app = apps::find("PVC").unwrap();
    let stats = Simulator::new(
        small_cfg(),
        Design::caba(caba::compress::Algo::Bdi),
        app,
        0.02,
    )
    .run();
    assert!(
        stats.md.hit_rate() > 0.7,
        "MD hit rate {} below plausible range",
        stats.md.hit_rate()
    );
}

#[test]
fn more_bandwidth_never_hurts() {
    for name in ["PVC", "SLA", "MM"] {
        let app = apps::find(name).unwrap();
        let base = Simulator::new(small_cfg(), Design::base(), app, 0.01).run();
        let mut cfg2 = small_cfg();
        cfg2.bw_scale *= 2.0;
        let doubled = Simulator::new(cfg2, Design::base(), app, 0.01).run();
        assert!(
            doubled.cycles <= base.cycles + base.cycles / 20,
            "{name}: 2x BW made it slower ({} -> {})",
            base.cycles,
            doubled.cycles
        );
    }
}
