//! Fault-injection harness for `caba serve` — in-process daemons on
//! temp sockets, driven through the same client path as `caba client`.
//!
//! The contract under test (DESIGN.md §serve): every failure mode gets a
//! typed, non-fatal answer. An injected worker panic yields exactly one
//! `"status":"error"`, never kills the daemon, never perturbs other
//! answers, and never poisons its key; a corrupt store entry quarantines
//! and recomputes — never wrong data; an overloaded queue sheds; a
//! deadline expiry leaves the job running so the retry is warm; a
//! malformed line leaves the connection usable; shutdown drains cleanly.

use caba::serve::json::Json;
use caba::serve::{self, ServeOpts, ServeSummary, Server, ServerHandle};
use caba::store::FaultPlan;
use std::io::{BufRead, BufReader, Write as _};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;

struct TestServer {
    base: PathBuf,
    socket: PathBuf,
    handle: ServerHandle,
    thread: Option<JoinHandle<anyhow::Result<ServeSummary>>>,
}

impl TestServer {
    /// Bind a daemon on fresh socket/store dirs under a per-test temp
    /// root; `tweak` adjusts the options (queue cap, fault plan) before
    /// bind. The store dir is kept across restarts of the same tag.
    fn start(tag: &str, tweak: impl FnOnce(&mut ServeOpts)) -> TestServer {
        let base =
            std::env::temp_dir().join(format!("caba_serve_faults_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        let socket = base.join("serve.sock");
        let mut opts = ServeOpts::new(&socket);
        opts.jobs = 2;
        opts.store_dir = Some(base.join("store"));
        tweak(&mut opts);
        let server = Server::bind(opts).unwrap();
        let handle = server.handle();
        let thread = Some(std::thread::spawn(move || server.run()));
        TestServer { base, socket, handle, thread }
    }

    fn request(&self, line: &str) -> Json {
        let resp = serve::client_request(&self.socket, line).unwrap();
        serve::json::parse(&resp).unwrap_or_else(|e| panic!("bad response {resp:?}: {e:#}"))
    }

    fn sweep(&self, app: &str, extra: &str) -> Json {
        self.request(&sweep_line(app, extra))
    }

    /// Drain and return the end-of-run summary; removes the temp root.
    fn finish(mut self) -> ServeSummary {
        self.handle.stop();
        let summary = self.thread.take().unwrap().join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&self.base);
        summary
    }

    /// Drain but keep the dirs (for restart-over-same-store tests).
    fn stop_keep_dirs(mut self) -> ServeSummary {
        self.handle.stop();
        self.thread.take().unwrap().join().unwrap().unwrap()
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.handle.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn sweep_line(app: &str, extra: &str) -> String {
    format!(
        "{{\"verb\":\"sweep\",\"app\":\"{app}\",\"design\":\"Base\",\"scale\":0.01,\
         \"set\":{{\"n_sms\":2,\"max_cycles\":150000}}{extra}}}"
    )
}

fn status(v: &Json) -> &str {
    v.get("status").and_then(Json::as_str).unwrap_or("<none>")
}

fn digest(v: &Json) -> String {
    v.get("stats_digest").and_then(Json::as_str).expect("ok response carries a digest").to_string()
}

#[test]
fn cold_then_warm_then_restart_warm_from_store() {
    let ts = TestServer::start("warm", |_| {});
    let a = ts.sweep("SLA", "");
    assert_eq!(status(&a), "ok");
    assert_eq!(a.get("source").and_then(Json::as_str), Some("cold"));
    let b = ts.sweep("SLA", "");
    assert_eq!(b.get("source").and_then(Json::as_str), Some("warm"));
    assert_eq!(digest(&a), digest(&b));
    let summary = ts.stop_keep_dirs();
    assert_eq!((summary.counters.cold, summary.counters.warm), (1, 1));

    // A restarted daemon over the same store dir answers warm on its
    // very first request — crash-safe persistence, end to end.
    let ts2 = TestServer::start("warm", |_| {});
    let c = ts2.sweep("SLA", "");
    assert_eq!(c.get("source").and_then(Json::as_str), Some("warm"));
    assert_eq!(digest(&a), digest(&c), "restart must serve bit-identical stats");
    let s2 = ts2.finish();
    assert_eq!(s2.store.unwrap().warm_hits, 1);
}

#[test]
fn injected_panic_is_isolated_typed_and_retryable() {
    // Job 0 (the first cold request) panics inside the worker.
    let plan = Arc::new(FaultPlan::parse("panic_at_job=0").unwrap());
    let fired = Arc::clone(&plan);
    let ts = TestServer::start("panic", move |o| o.fault = Some(plan));

    let err = ts.sweep("SLA", "");
    assert_eq!(status(&err), "error");
    let msg = err.get("message").and_then(Json::as_str).unwrap();
    assert!(msg.contains("injected fault"), "typed error must carry the panic message: {msg}");
    assert_eq!(fired.injected(), 1);

    // The daemon is alive, other points still work, and the failed key
    // was never cached — its retry recomputes and succeeds.
    assert_eq!(status(&ts.request(r#"{"verb":"ping"}"#)), "ok");
    assert_eq!(status(&ts.sweep("PVC", "")), "ok");
    let retry = ts.sweep("SLA", "");
    assert_eq!(status(&retry), "ok");
    assert_eq!(retry.get("source").and_then(Json::as_str), Some("cold"));

    let summary = ts.finish();
    assert_eq!(summary.counters.job_errors, 1);
    assert_eq!(summary.counters.cold, 2);
}

#[test]
fn unaffected_answers_are_bit_identical_with_a_fault_present() {
    // Clean reference digests first, then the same points through a
    // daemon whose second job panics.
    let ts = TestServer::start("bitident_clean", |_| {});
    let clean_sla = digest(&ts.sweep("SLA", ""));
    let clean_pvc = digest(&ts.sweep("PVC", ""));
    ts.finish();

    let plan = Arc::new(FaultPlan::parse("panic_at_job=1").unwrap());
    let ts = TestServer::start("bitident_fault", move |o| o.fault = Some(plan));
    assert_eq!(digest(&ts.sweep("SLA", "")), clean_sla);
    assert_eq!(status(&ts.sweep("PVC", "")), "error");
    assert_eq!(digest(&ts.sweep("PVC", "")), clean_pvc, "recovery must be bit-identical");
    ts.finish();
}

#[test]
fn full_queue_sheds_instead_of_blocking() {
    // queue_cap=0: every cold admission sheds. Shedding holds no
    // resources, so the same request succeeds once capacity returns (here:
    // never, but the daemon stays responsive and counts the rejections).
    let ts = TestServer::start("shed", |o| o.queue_cap = 0);
    for _ in 0..3 {
        let v = ts.sweep("SLA", "");
        assert_eq!(status(&v), "shed");
    }
    assert_eq!(status(&ts.request(r#"{"verb":"ping"}"#)), "ok");
    let summary = ts.finish();
    assert_eq!(summary.counters.shed, 3);
    assert_eq!(summary.counters.cold, 0);
}

#[test]
fn deadline_expiry_leaves_the_job_running_and_warms_the_retry() {
    // Job 0 stalls 1.5 s; the client only waits 50 ms. The answer is a
    // typed deadline, the job keeps running, and the retry is answered
    // from the cache/store (or by deduping onto the still-running job) —
    // never recomputed from scratch a second time.
    let plan = Arc::new(FaultPlan::parse("slow_at_job=0,slow_job_ms=1500").unwrap());
    let ts = TestServer::start("deadline", move |o| o.fault = Some(plan));
    let v = ts.sweep("SLA", ",\"deadline_ms\":50");
    assert_eq!(status(&v), "deadline");
    let retry = ts.sweep("SLA", ",\"deadline_ms\":30000");
    assert_eq!(status(&retry), "ok");
    let summary = ts.finish();
    assert_eq!(summary.counters.deadline_expired, 1);
    assert_eq!(summary.store.unwrap().puts, 1, "the deadline'd job must have completed once");
}

#[test]
fn corrupt_store_entry_quarantines_and_recomputes_on_restart() {
    // First daemon persists one entry whose write is checksum-flipped —
    // the response itself is correct (in-memory stats), the disk is not.
    let plan = Arc::new(FaultPlan::parse("flip_checksum_at=0").unwrap());
    let ts = TestServer::start("corrupt", move |o| o.fault = Some(plan));
    let first = ts.sweep("SLA", "");
    assert_eq!(status(&first), "ok");
    let reference = digest(&first);
    ts.stop_keep_dirs();

    // The restarted daemon must never serve the corrupt bytes: the entry
    // quarantines on read, the point recomputes cold, and the digest
    // matches the pre-corruption truth.
    let ts2 = TestServer::start("corrupt", |_| {});
    let v = ts2.sweep("SLA", "");
    assert_eq!(status(&v), "ok");
    assert_eq!(v.get("source").and_then(Json::as_str), Some("cold"));
    assert_eq!(digest(&v), reference, "recomputed stats must match the original");
    let summary = ts2.finish();
    let store = summary.store.unwrap();
    assert_eq!(store.quarantined, 1);
    assert_eq!(store.puts, 1, "the healed entry must be re-persisted");
}

#[test]
fn bad_lines_answer_typed_errors_and_keep_the_connection_usable() {
    let ts = TestServer::start("badline", |_| {});
    // One persistent connection: garbage, unknown verb, then a valid ping.
    {
        let stream = UnixStream::connect(&ts.socket).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut roundtrip = |line: &str| -> Json {
            writer.write_all(line.as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
            writer.flush().unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            serve::json::parse(resp.trim()).unwrap()
        };
        assert_eq!(status(&roundtrip("{not json")), "error");
        assert_eq!(status(&roundtrip(r#"{"verb":"frobnicate"}"#)), "error");
        assert_eq!(status(&roundtrip(r#"{"verb":"sweep","app":"NOPE"}"#)), "error");
        assert_eq!(status(&roundtrip(r#"{"verb":"ping"}"#)), "ok");
    }

    let summary = ts.finish();
    assert_eq!(summary.counters.bad_requests, 3);
    assert_eq!(summary.counters.requests, 4);
}

#[test]
fn shutdown_verb_drains_gracefully_and_removes_the_socket() {
    let ts = TestServer::start("shutdown", |_| {});
    assert_eq!(status(&ts.sweep("SLA", "")), "ok");
    let v = ts.request(r#"{"verb":"shutdown"}"#);
    assert_eq!(status(&v), "ok");
    assert_eq!(v.get("draining").and_then(Json::as_bool), Some(true));

    // run() returns on its own — no handle.stop() needed — and the
    // socket file is gone afterwards.
    let mut ts = ts;
    let summary = ts.thread.take().unwrap().join().unwrap().unwrap();
    assert_eq!(summary.counters.cold, 1);
    assert!(!ts.socket.exists(), "drained server must remove its socket");
    assert!(
        UnixStream::connect(&ts.socket).is_err(),
        "no listener may survive the drain"
    );
    let _ = std::fs::remove_dir_all(&ts.base);
}
