//! Observability harness for `caba serve` — in-process daemons on temp
//! sockets exercising the three surfaces from DESIGN.md §5d:
//!
//! * the `metrics` verb must return a structurally valid Prometheus text
//!   exposition whose counters match what the daemon actually did;
//! * every response — ok, error, shed — must echo a `request_id`, and
//!   ids must be dense and monotonic per daemon;
//! * the `stats` verb must surface the queue gauges, latency
//!   percentiles, and the full store counters;
//! * the `trace` verb's spans must decode and export to a balanced
//!   Chrome trace JSON;
//! * and the whole layer must be observation-only: an engine with
//!   metrics attached produces bit-identical `SimStats` to one without,
//!   and no new key enters the fingerprinted config surface.

use caba::obs::prom;
use caba::serve::json::Json;
use caba::serve::{self, ServeOpts, ServeSummary, Server, ServerHandle};
use caba::sim::designs::Design;
use caba::sweep::{RunCache, SweepEngine, SweepJob};
use caba::telemetry::export::server_trace_json;
use caba::workload::apps;
use caba::SimConfig;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread::JoinHandle;

struct TestServer {
    base: PathBuf,
    socket: PathBuf,
    handle: ServerHandle,
    thread: Option<JoinHandle<anyhow::Result<ServeSummary>>>,
}

impl TestServer {
    fn start(tag: &str, tweak: impl FnOnce(&mut ServeOpts)) -> TestServer {
        let base =
            std::env::temp_dir().join(format!("caba_serve_obs_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        let socket = base.join("serve.sock");
        let mut opts = ServeOpts::new(&socket);
        opts.jobs = 2;
        opts.store_dir = Some(base.join("store"));
        tweak(&mut opts);
        let server = Server::bind(opts).unwrap();
        let handle = server.handle();
        let thread = Some(std::thread::spawn(move || server.run()));
        TestServer { base, socket, handle, thread }
    }

    fn request(&self, line: &str) -> Json {
        let resp = serve::client_request(&self.socket, line).unwrap();
        serve::json::parse(&resp).unwrap_or_else(|e| panic!("bad response {resp:?}: {e:#}"))
    }

    fn sweep(&self, app: &str) -> Json {
        self.request(&format!(
            "{{\"verb\":\"sweep\",\"app\":\"{app}\",\"design\":\"Base\",\"scale\":0.01,\
             \"set\":{{\"n_sms\":2,\"max_cycles\":150000}}}}"
        ))
    }

    fn finish(mut self) -> ServeSummary {
        self.handle.stop();
        let summary = self.thread.take().unwrap().join().unwrap().unwrap();
        let _ = std::fs::remove_dir_all(&self.base);
        summary
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.handle.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn status(v: &Json) -> &str {
    v.get("status").and_then(Json::as_str).unwrap_or("<none>")
}

fn request_id(v: &Json) -> u64 {
    v.get("request_id").and_then(Json::as_u64).expect("every response must echo a request_id")
}

/// One sample line's value out of an exposition (`name value`).
fn sample(text: &str, name: &str) -> Option<f64> {
    text.lines()
        .find(|l| l.split_whitespace().next() == Some(name))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

#[test]
fn metrics_verb_returns_a_valid_exposition_that_matches_activity() {
    let ts = TestServer::start("metrics", |_| {});
    assert_eq!(status(&ts.sweep("SLA")), "ok"); // cold
    assert_eq!(status(&ts.sweep("SLA")), "ok"); // warm
    let v = ts.request(r#"{"verb":"metrics"}"#);
    assert_eq!(status(&v), "ok");
    let text = v.get("metrics").and_then(Json::as_str).expect("metrics payload string");

    prom::validate(text).unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));

    // The metrics request itself is counted before it renders: 2 sweeps
    // + this scrape = 3.
    assert_eq!(sample(text, "caba_serve_requests_total"), Some(3.0));
    assert_eq!(sample(text, "caba_serve_cold_total"), Some(1.0));
    assert_eq!(sample(text, "caba_serve_warm_total"), Some(1.0));
    assert_eq!(sample(text, "caba_jobs_ok_total"), Some(1.0));
    assert_eq!(sample(text, "caba_store_puts_total"), Some(1.0));
    // The cold job sat in the queue at least momentarily — the
    // queue-wait histogram must carry its observation.
    assert_eq!(sample(text, "caba_serve_queue_wait_us_count"), Some(1.0));
    assert_eq!(sample(text, "caba_job_wall_us_count"), Some(1.0));
    // Request latency histogram saw the two sweeps (the scrape's own
    // span finishes after rendering).
    assert_eq!(sample(text, "caba_serve_request_us_count"), Some(2.0));

    // The in-process registry agrees with the wire exposition.
    assert!(ts.handle.metrics().jobs.queue_wait_us.count() >= 1);
    ts.finish();
}

#[test]
fn every_response_kind_echoes_a_dense_monotonic_request_id() {
    let ts = TestServer::start("reqid", |_| {});
    let a = ts.request(r#"{"verb":"ping"}"#);
    assert_eq!(status(&a), "ok");
    assert_eq!(request_id(&a), 1);
    let b = ts.sweep("SLA");
    assert_eq!(status(&b), "ok");
    assert_eq!(request_id(&b), 2);
    let c = ts.request(r#"{"verb":"frobnicate"}"#);
    assert_eq!(status(&c), "error");
    assert_eq!(request_id(&c), 3);
    let d = ts.request("{not json");
    assert_eq!(status(&d), "error");
    assert_eq!(request_id(&d), 4);
    ts.finish();

    // Shed responses carry ids too (queue_cap=0 rejects every cold job).
    let ts = TestServer::start("reqid_shed", |o| o.queue_cap = 0);
    let v = ts.sweep("SLA");
    assert_eq!(status(&v), "shed");
    assert_eq!(request_id(&v), 1);
    ts.finish();
}

#[test]
fn stats_verb_surfaces_queue_gauges_percentiles_and_store_counters() {
    let ts = TestServer::start("stats", |_| {});
    assert_eq!(status(&ts.sweep("SLA")), "ok");
    assert_eq!(status(&ts.sweep("SLA")), "ok");
    let v = ts.request(r#"{"verb":"stats"}"#);
    assert_eq!(status(&v), "ok");
    let u = |k: &str| v.get(k).and_then(Json::as_u64).unwrap_or_else(|| panic!("missing {k}"));
    assert_eq!(u("cold"), 1);
    assert_eq!(u("warm"), 1);
    assert_eq!(u("queue_depth"), 0, "nothing queued at rest");
    assert_eq!(u("queue_depth_hwm"), 1, "the one cold job peaked the queue");
    assert!(u("request_p50_us") > 0, "two completed requests give nonzero p50");
    assert!(u("request_p99_us") >= u("request_p50_us"));
    assert_eq!(u("store_puts"), 1);
    assert_eq!(u("store_quarantined"), 0);
    assert_eq!(u("store_put_errors"), 0);
    // The cold miss probed the store before simulating.
    assert!(u("store_misses") >= 1);
    let summary = ts.finish();
    assert_eq!(summary.queue_depth_hwm, 1);
    assert!(summary.request_p50_us > 0);
}

#[test]
fn trace_spans_decode_and_export_to_balanced_chrome_json() {
    let ts = TestServer::start("trace", |_| {});
    assert_eq!(status(&ts.sweep("SLA")), "ok");
    assert_eq!(status(&ts.request(r#"{"verb":"ping"}"#)), "ok");
    let v = ts.request(r#"{"verb":"trace"}"#);
    assert_eq!(status(&v), "ok");
    let spans: Vec<_> = v
        .get("spans")
        .and_then(Json::elements)
        .expect("trace response carries spans")
        .iter()
        .filter_map(serve::span_from_json)
        .collect();
    // The trace request itself isn't in the ring yet (its span is pushed
    // after responding), so: the sweep and the ping.
    assert_eq!(spans.len(), 2);
    assert_eq!(spans[0].verb, "sweep");
    assert_eq!(spans[0].outcome, "cold");
    assert!(spans[0].queue_wait_us > 0 || spans[0].exec_us > 0);
    assert_eq!(spans[1].verb, "ping");

    let dropped = v.get("dropped").and_then(Json::as_u64).unwrap();
    let json = server_trace_json(&spans, "test", dropped);
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    assert_eq!(json.matches('[').count(), json.matches(']').count());
    assert!(json.contains("caba serve"));
    assert!(json.contains("\"sweep #"));
    ts.finish();
}

/// The observation-only contract: attaching the metrics registry to an
/// engine changes nothing about what the simulation computes, and the
/// fingerprinted config surface gains no keys from this layer.
#[test]
fn metrics_do_not_perturb_simulation() {
    let mut cfg = SimConfig::default();
    cfg.n_sms = 2;
    cfg.max_cycles = 150_000;
    let app = apps::find("SLA").unwrap();
    let job = SweepJob::new(app, Design::caba(caba::compress::Algo::Bdi), cfg, 0.01);

    let plain = SweepEngine::with_cache(1, Arc::new(RunCache::new()));
    let metered = SweepEngine::with_cache(1, Arc::new(RunCache::new()))
        .with_metrics(Arc::new(caba::obs::JobMetrics::default()));
    let a = plain.try_run_one(&job).unwrap();
    let b = metered.try_run_one(&job).unwrap();
    assert_eq!(a, b, "metrics must be observation-only");

    // No obs knob may enter the fingerprint: the key set is pinned.
    assert_eq!(SimConfig::KEYS.len(), 51, "obs layer must not grow the fingerprinted surface");
}
