//! Cross-design invariants: the orderings the paper's evaluation rests on
//! must hold on this simulator for compressible, bandwidth-bound workloads.

use caba::compress::Algo;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::SimConfig;

fn cfg() -> SimConfig {
    let mut c = SimConfig::default();
    // Shrink the chip but keep the paper's compute:bandwidth balance —
    // with 4 of 15 SMs and full bandwidth nothing is bandwidth-bound and
    // compression has nothing to accelerate.
    c.n_sms = 4;
    c.bw_scale = 4.0 / 15.0;
    c.max_cycles = 2_000_000;
    c
}

fn ipc(app: &'static caba::workload::apps::AppSpec, d: Design) -> f64 {
    Simulator::new(cfg(), d, app, 0.02).run().ipc()
}

#[test]
fn compression_speeds_up_bandwidth_bound_compressible_apps() {
    for name in ["PVC", "MM", "SLA", "LPS"] {
        let app = apps::find(name).unwrap();
        let base = ipc(app, Design::base());
        let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
        assert!(
            caba_ipc > base * 1.05,
            "{name}: CABA-BDI {caba_ipc:.3} vs Base {base:.3}"
        );
    }
}

#[test]
fn ideal_upper_bounds_caba() {
    for name in ["PVC", "LPS"] {
        let app = apps::find(name).unwrap();
        let ideal = ipc(app, Design::ideal_bdi());
        let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
        // Paper: CABA within 2.8% of Ideal on average; tolerate slack on a
        // single app, but Ideal must never lose to CABA by more than noise.
        assert!(
            ideal >= caba_ipc * 0.97,
            "{name}: Ideal {ideal:.3} < CABA {caba_ipc:.3}"
        );
    }
}

#[test]
fn caba_close_to_hardware_designs() {
    // Paper §7.1: CABA-BDI within a few % of HW-BDI.
    let app = apps::find("PVC").unwrap();
    let hw = ipc(app, Design::hw_bdi());
    let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
    let gap = (hw - caba_ipc) / hw;
    assert!(gap < 0.15, "CABA {caba_ipc:.3} vs HW {hw:.3} gap {gap:.3}");
    assert!(caba_ipc <= hw * 1.05, "CABA should not beat dedicated HW by much");
}

#[test]
fn compressed_designs_cut_dram_traffic() {
    let app = apps::find("PVC").unwrap();
    for d in [
        Design::hw_bdi_mem(),
        Design::hw_bdi(),
        Design::caba(Algo::Bdi),
        Design::ideal_bdi(),
    ] {
        let stats = Simulator::new(cfg(), d, app, 0.02).run();
        assert!(
            stats.dram.compression_ratio() > 2.0,
            "{}: ratio {}",
            d.name,
            stats.dram.compression_ratio()
        );
    }
}

#[test]
fn caba_assist_warps_actually_run() {
    let app = apps::find("PVC").unwrap();
    let stats = Simulator::new(cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
    assert!(stats.caba.decompress_warps > 100);
    assert!(stats.caba.compress_warps > 10);
    assert!(stats.caba.assist_insts_issued > stats.caba.decompress_warps);
    // Low-priority work must overwhelmingly use idle slots.
    assert!(stats.caba.assist_insts_idle_slots > 0);
    // Hardware designs never run assist warps.
    let hw = Simulator::new(cfg(), Design::hw_bdi(), app, 0.02).run();
    assert_eq!(hw.caba.decompress_warps, 0);
    assert_eq!(hw.caba.assist_insts_issued, 0);
}

#[test]
fn algorithms_differ_by_data_pattern() {
    // Fig. 13: MM/PVC (low-dynamic-range) favour BDI; LPS (sparse-narrow)
    // favours FPC's compression ratio.
    let pvc = apps::find("PVC").unwrap();
    let bdi = Simulator::new(cfg(), Design::caba(Algo::Bdi), pvc, 0.02).run();
    let fpc = Simulator::new(cfg(), Design::caba(Algo::Fpc), pvc, 0.02).run();
    assert!(
        bdi.dram.compression_ratio() > fpc.dram.compression_ratio(),
        "PVC: BDI {} vs FPC {}",
        bdi.dram.compression_ratio(),
        fpc.dram.compression_ratio()
    );
    let lps = apps::find("LPS").unwrap();
    let bdi = Simulator::new(cfg(), Design::caba(Algo::Bdi), lps, 0.02).run();
    let fpc = Simulator::new(cfg(), Design::caba(Algo::Fpc), lps, 0.02).run();
    assert!(
        fpc.dram.compression_ratio() > bdi.dram.compression_ratio(),
        "LPS: FPC {} vs BDI {}",
        fpc.dram.compression_ratio(),
        bdi.dram.compression_ratio()
    );
}

#[test]
fn best_of_all_ratio_dominates() {
    let app = apps::find("JPEG").unwrap();
    let best = Simulator::new(cfg(), Design::caba(Algo::BestOfAll), app, 0.02).run();
    for algo in Algo::CONCRETE {
        let one = Simulator::new(cfg(), Design::caba(algo), app, 0.02).run();
        assert!(
            best.dram.compression_ratio() >= one.dram.compression_ratio() * 0.98,
            "BestOfAll {} < {algo:?} {}",
            best.dram.compression_ratio(),
            one.dram.compression_ratio()
        );
    }
}

#[test]
fn energy_drops_with_compression() {
    // Fig. 10: compression cuts DRAM traffic and runtime → lower energy.
    let app = apps::find("PVC").unwrap();
    let em = caba::energy::EnergyModel::default();
    let base = Simulator::new(cfg(), Design::base(), app, 0.02).run();
    let caba_stats = Simulator::new(cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
    let e_base = em.evaluate(&base, false, false).total_mj();
    let e_caba = em.evaluate(&caba_stats, true, false).total_mj();
    assert!(e_caba < e_base, "energy {e_caba} !< {e_base}");
    // DRAM component specifically (paper: −29.5% DRAM power).
    let d_base = em.evaluate(&base, false, false).dram_total_mj();
    let d_caba = em.evaluate(&caba_stats, true, false).dram_total_mj();
    assert!(d_caba < d_base * 0.7, "dram energy {d_caba} vs {d_base}");
}

#[test]
fn fig16_variants_run_and_stay_sane() {
    let app = apps::find("MM").unwrap();
    let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
    for d in [Design::caba_uncompressed_l2(), Design::caba_direct_load()] {
        let v = ipc(app, d);
        assert!(
            v > caba_ipc * 0.7 && v < caba_ipc * 1.4,
            "{}: {v:.3} vs CABA {caba_ipc:.3}",
            d.name
        );
    }
}

#[test]
fn fig15_l1_compression_can_hurt() {
    // The paper: L1 cache compression "can severely degrade the
    // performance of some applications" (every hit pays decompression)
    // while capacity-sensitive apps benefit — i.e. the effect is mixed,
    // with at least one loser among reuse-heavy apps.
    let mut worst = f64::INFINITY;
    let mut best = 0.0f64;
    for name in ["MM", "hs", "KM", "RAY"] {
        let app = apps::find(name).unwrap();
        let plain = ipc(app, Design::caba(Algo::Bdi));
        let l1c = ipc(app, Design::caba_cache_compressed(4, 1));
        let rel = l1c / plain;
        worst = worst.min(rel);
        best = best.max(rel);
    }
    assert!(worst < 1.0, "no app hurt by L1 compression (worst rel {worst:.3})");
    assert!(best > 0.95, "L1 compression should not hurt everyone (best {best:.3})");
}
