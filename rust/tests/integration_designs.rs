//! Cross-design invariants: the orderings the paper's evaluation rests on
//! must hold on this simulator for compressible, bandwidth-bound workloads.

use caba::compress::Algo;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::SimConfig;

fn cfg() -> SimConfig {
    let mut c = SimConfig::default();
    // Shrink the chip but keep the paper's compute:bandwidth balance —
    // with 4 of 15 SMs and full bandwidth nothing is bandwidth-bound and
    // compression has nothing to accelerate.
    c.n_sms = 4;
    c.bw_scale = 4.0 / 15.0;
    c.max_cycles = 2_000_000;
    c
}

fn ipc(app: &'static caba::workload::apps::AppSpec, d: Design) -> f64 {
    Simulator::new(cfg(), d, app, 0.02).run().ipc()
}

#[test]
fn compression_speeds_up_bandwidth_bound_compressible_apps() {
    for name in ["PVC", "MM", "SLA", "LPS"] {
        let app = apps::find(name).unwrap();
        let base = ipc(app, Design::base());
        let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
        assert!(
            caba_ipc > base * 1.05,
            "{name}: CABA-BDI {caba_ipc:.3} vs Base {base:.3}"
        );
    }
}

#[test]
fn ideal_upper_bounds_caba() {
    for name in ["PVC", "LPS"] {
        let app = apps::find(name).unwrap();
        let ideal = ipc(app, Design::ideal_bdi());
        let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
        // Paper: CABA within 2.8% of Ideal on average; tolerate slack on a
        // single app, but Ideal must never lose to CABA by more than noise.
        assert!(
            ideal >= caba_ipc * 0.97,
            "{name}: Ideal {ideal:.3} < CABA {caba_ipc:.3}"
        );
    }
}

#[test]
fn caba_close_to_hardware_designs() {
    // Paper §7.1: CABA-BDI within a few % of HW-BDI.
    let app = apps::find("PVC").unwrap();
    let hw = ipc(app, Design::hw_bdi());
    let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
    let gap = (hw - caba_ipc) / hw;
    assert!(gap < 0.15, "CABA {caba_ipc:.3} vs HW {hw:.3} gap {gap:.3}");
    assert!(caba_ipc <= hw * 1.05, "CABA should not beat dedicated HW by much");
}

#[test]
fn compressed_designs_cut_dram_traffic() {
    let app = apps::find("PVC").unwrap();
    for d in [
        Design::hw_bdi_mem(),
        Design::hw_bdi(),
        Design::caba(Algo::Bdi),
        Design::ideal_bdi(),
    ] {
        let stats = Simulator::new(cfg(), d, app, 0.02).run();
        assert!(
            stats.dram.compression_ratio() > 2.0,
            "{}: ratio {}",
            d.name,
            stats.dram.compression_ratio()
        );
    }
}

#[test]
fn caba_assist_warps_actually_run() {
    let app = apps::find("PVC").unwrap();
    let stats = Simulator::new(cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
    assert!(stats.caba.decompress_warps > 100);
    assert!(stats.caba.compress_warps > 10);
    assert!(stats.caba.assist_insts_issued > stats.caba.decompress_warps);
    // Low-priority work must overwhelmingly use idle slots.
    assert!(stats.caba.assist_insts_idle_slots > 0);
    // Hardware designs never run assist warps.
    let hw = Simulator::new(cfg(), Design::hw_bdi(), app, 0.02).run();
    assert_eq!(hw.caba.decompress_warps, 0);
    assert_eq!(hw.caba.assist_insts_issued, 0);
}

#[test]
fn algorithms_differ_by_data_pattern() {
    // Fig. 13: MM/PVC (low-dynamic-range) favour BDI; LPS (sparse-narrow)
    // favours FPC's compression ratio.
    let pvc = apps::find("PVC").unwrap();
    let bdi = Simulator::new(cfg(), Design::caba(Algo::Bdi), pvc, 0.02).run();
    let fpc = Simulator::new(cfg(), Design::caba(Algo::Fpc), pvc, 0.02).run();
    assert!(
        bdi.dram.compression_ratio() > fpc.dram.compression_ratio(),
        "PVC: BDI {} vs FPC {}",
        bdi.dram.compression_ratio(),
        fpc.dram.compression_ratio()
    );
    let lps = apps::find("LPS").unwrap();
    let bdi = Simulator::new(cfg(), Design::caba(Algo::Bdi), lps, 0.02).run();
    let fpc = Simulator::new(cfg(), Design::caba(Algo::Fpc), lps, 0.02).run();
    assert!(
        fpc.dram.compression_ratio() > bdi.dram.compression_ratio(),
        "LPS: FPC {} vs BDI {}",
        fpc.dram.compression_ratio(),
        bdi.dram.compression_ratio()
    );
}

#[test]
fn best_of_all_ratio_dominates() {
    let app = apps::find("JPEG").unwrap();
    let best = Simulator::new(cfg(), Design::caba(Algo::BestOfAll), app, 0.02).run();
    for algo in Algo::CONCRETE {
        let one = Simulator::new(cfg(), Design::caba(algo), app, 0.02).run();
        assert!(
            best.dram.compression_ratio() >= one.dram.compression_ratio() * 0.98,
            "BestOfAll {} < {algo:?} {}",
            best.dram.compression_ratio(),
            one.dram.compression_ratio()
        );
    }
}

#[test]
fn energy_drops_with_compression() {
    // Fig. 10: compression cuts DRAM traffic and runtime → lower energy.
    let app = apps::find("PVC").unwrap();
    let em = caba::energy::EnergyModel::default();
    let base = Simulator::new(cfg(), Design::base(), app, 0.02).run();
    let caba_stats = Simulator::new(cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
    let e_base = em.evaluate(&base, false, false).total_mj();
    let e_caba = em.evaluate(&caba_stats, true, false).total_mj();
    assert!(e_caba < e_base, "energy {e_caba} !< {e_base}");
    // DRAM component specifically (paper: −29.5% DRAM power).
    let d_base = em.evaluate(&base, false, false).dram_total_mj();
    let d_caba = em.evaluate(&caba_stats, true, false).dram_total_mj();
    assert!(d_caba < d_base * 0.7, "dram energy {d_caba} vs {d_base}");
}

#[test]
fn fig16_variants_run_and_stay_sane() {
    let app = apps::find("MM").unwrap();
    let caba_ipc = ipc(app, Design::caba(Algo::Bdi));
    for d in [Design::caba_uncompressed_l2(), Design::caba_direct_load()] {
        let v = ipc(app, d);
        assert!(
            v > caba_ipc * 0.7 && v < caba_ipc * 1.4,
            "{}: {v:.3} vs CABA {caba_ipc:.3}",
            d.name
        );
    }
}

#[test]
fn fig15_l1_compression_can_hurt() {
    // The paper: L1 cache compression "can severely degrade the
    // performance of some applications" (every hit pays decompression)
    // while capacity-sensitive apps benefit — i.e. the effect is mixed,
    // with at least one loser among reuse-heavy apps.
    let mut worst = f64::INFINITY;
    let mut best = 0.0f64;
    for name in ["MM", "hs", "KM", "RAY"] {
        let app = apps::find(name).unwrap();
        let plain = ipc(app, Design::caba(Algo::Bdi));
        let l1c = ipc(app, Design::caba_cache_compressed(4, 1));
        let rel = l1c / plain;
        worst = worst.min(rel);
        best = best.max(rel);
    }
    assert!(worst < 1.0, "no app hurt by L1 compression (worst rel {worst:.3})");
    assert!(best > 0.95, "L1 compression should not hurt everyone (best {best:.3})");
}

// ---------------------------------------------------------------- §8.1 memo

#[test]
fn memo_hit_rate_emerges_from_value_redundancy() {
    // The hit rate is *measured* through the per-SM LUTs, so it must track
    // the operand-value redundancy of the workload: FRAG (70% shared,
    // head-heavy 2048-class pool) clearly above MCX (5% shared over 64K
    // classes), with the low-redundancy control close to zero.
    let rate = |name: &str| {
        let app = apps::find(name).unwrap();
        let s = Simulator::new(cfg(), Design::caba_memo(), app, 0.05).run();
        assert!(s.finished, "{name} did not drain");
        assert!(s.caba.memo_lookups > 0, "{name}: no lookups");
        (s.caba.memo_hit_rate().unwrap(), s)
    };
    let (frag, frag_stats) = rate("FRAG");
    let (mcx, _) = rate("MCX");
    assert!(frag > 0.10, "FRAG hit rate {frag:.3} too low for a 70%-shared stream");
    assert!(mcx < 0.08, "MCX hit rate {mcx:.3} too high for a 5%-shared stream");
    assert!(frag > mcx + 0.05, "redundancy ordering lost: {frag:.3} vs {mcx:.3}");
    // Installs happen and the LUT actually fills (GEO's unique+large-pool
    // stream installs more distinct keys than the LUT holds → evictions).
    assert!(frag_stats.caba.memo_installs > 0);
    let geo = Simulator::new(cfg(), Design::caba_memo(), apps::find("GEO").unwrap(), 0.08).run();
    assert!(geo.caba.memo_evictions > 0, "GEO never evicted — capacity not modeled?");
}

#[test]
fn memo_zero_budget_disables_cleanly() {
    // `memo_lut_bytes=0` leaves no LUT to carve: the memo design must
    // degrade to plain SFU execution (no lookups, no hits) and still
    // drain — capacity is a real, configuration-visible resource.
    let app = apps::find("FRAG").unwrap();
    let mut zero = cfg();
    zero.memo_lut_bytes = 0;
    let s = Simulator::new(zero, Design::caba_memo(), app, 0.02).run();
    assert!(s.finished);
    assert_eq!(s.caba.memo_lookups, 0);
    assert_eq!(s.caba.memo_hits, 0);
    assert_eq!(s.caba.memo_installs, 0);
    // And with the default budget the same workload does probe.
    let s = Simulator::new(cfg(), Design::caba_memo(), app, 0.02).run();
    assert!(s.caba.memo_lookups > 0);
}

#[test]
fn memo_speeds_up_sfu_heavy_compute_bound_apps() {
    // FRAG is SFU-pipeline bound (6 SFU ops/iter × 4-cycle occupancy);
    // every memo hit frees the pipe and serves the result at shared-memory
    // latency, so CABA-Memo must beat Base. On the near-unique control the
    // lookup overhead must stay bounded (it hides under the SFU shadow).
    let run = |name: &str, d: Design| {
        Simulator::new(cfg(), d, apps::find(name).unwrap(), 0.05).run().ipc()
    };
    let base = run("FRAG", Design::base());
    let memo = run("FRAG", Design::caba_memo());
    assert!(memo > base * 1.01, "FRAG: memo {memo:.3} vs base {base:.3}");
    let base = run("MCX", Design::base());
    let memo = run("MCX", Design::caba_memo());
    assert!(memo > base * 0.85, "MCX: memo overhead too large ({memo:.3} vs {base:.3})");
}

#[test]
fn memo_smem_hungry_app_gets_a_smaller_or_no_lut() {
    // hs fills most of its shared memory; the carve must shrink and the
    // run must still complete (memoization silently degrades, never
    // crashes).
    let app = apps::find("hs").unwrap();
    let s = Simulator::new(cfg(), Design::caba_memo(), app, 0.02).run();
    assert!(s.finished);
}

#[test]
fn memo_hybrid_compresses_and_memoizes() {
    let app = apps::find("FRAG").unwrap(); // compressible float data
    let s = Simulator::new(cfg(), Design::caba_memo_hybrid(), app, 0.02).run();
    assert!(s.finished);
    assert!(s.caba.memo_lookups > 0, "hybrid lost its memo half");
    assert!(
        s.dram.compression_ratio() > 1.05,
        "hybrid lost its compression half: {}",
        s.dram.compression_ratio()
    );
}
