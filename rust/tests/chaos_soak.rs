//! Chaos soak: one in-process daemon under a seeded disk/connection
//! fault plan AND a store byte budget, hammered by concurrent retrying
//! clients. The resilience contract under test (DESIGN.md §5e):
//!
//! * the daemon never crashes — every request gets an answer or a
//!   dropped connection the client recovers from;
//! * every `ok` is **bit-identical** to the clean (fault-free,
//!   unbounded) run of the same point — eviction, ENOSPC, read EIO and
//!   dropped connections degrade caching, never correctness;
//! * the committed `.run` bytes on disk never exceed the budget;
//! * the retrying client converges: no request exhausts its backoff
//!   budget under this plan.
//!
//! Everything is seeded (`FaultPlan` indices, client jitter seeds), so a
//! failure replays exactly.

use caba::client::{Conn, RetryPolicy};
use caba::serve::{ServeOpts, ServeSummary, Server, ServerHandle};
use caba::store::FaultPlan;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct TestServer {
    base: PathBuf,
    socket: PathBuf,
    handle: ServerHandle,
    thread: Option<JoinHandle<anyhow::Result<ServeSummary>>>,
}

impl TestServer {
    fn start(tag: &str, tweak: impl FnOnce(&mut ServeOpts)) -> TestServer {
        let base =
            std::env::temp_dir().join(format!("caba_chaos_soak_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&base).unwrap();
        let socket = base.join("serve.sock");
        let mut opts = ServeOpts::new(&socket);
        opts.jobs = 2;
        opts.store_dir = Some(base.join("store"));
        tweak(&mut opts);
        let server = Server::bind(opts).unwrap();
        let handle = server.handle();
        let thread = Some(std::thread::spawn(move || server.run()));
        TestServer { base, socket, handle, thread }
    }

    fn store_dir(&self) -> PathBuf {
        self.base.join("store")
    }

    /// Drain; the `Result`/join doubles as the never-crashed assert.
    fn finish(mut self) -> ServeSummary {
        self.handle.stop();
        let summary =
            self.thread.take().unwrap().join().expect("daemon thread must not panic").unwrap();
        let _ = std::fs::remove_dir_all(&self.base);
        summary
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.handle.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        let _ = std::fs::remove_dir_all(&self.base);
    }
}

fn sweep_line(app: &str, scale: f64) -> String {
    format!(
        "{{\"verb\":\"sweep\",\"app\":\"{app}\",\"design\":\"Base\",\"scale\":{scale},\
         \"set\":{{\"n_sms\":2,\"max_cycles\":150000}}}}"
    )
}

/// The four distinct sweep points the soak cycles through. Tiny configs:
/// the soak is about the service fabric, not simulator throughput.
fn points() -> Vec<String> {
    ["SLA", "PVC", "MM", "TRA"].iter().map(|app| sweep_line(app, 0.01)).collect()
}

/// Sum of committed entry bytes on disk (quarantine/temp files excluded,
/// exactly as the budget accounts them).
fn run_bytes(dir: &Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().ends_with(".run"))
                .filter_map(|e| e.metadata().ok())
                .map(|m| m.len())
                .sum()
        })
        .unwrap_or(0)
}

fn digest_of(resp: &caba::client::Response) -> String {
    match resp {
        caba::client::Response::Ok { digest: Some(d), .. } => d.clone(),
        other => panic!("expected an ok response with a digest, got {other:?}"),
    }
}

#[test]
fn chaos_soak_faulted_budgeted_daemon_stays_correct() {
    // ---- Pass 1: clean reference. Unbounded store, no faults. ----
    let clean = TestServer::start("clean", |_| {});
    let mut reference = Vec::new();
    {
        let mut conn = Conn::new(&clean.socket, RetryPolicy::default());
        for line in points() {
            let resp = conn.request(&line).unwrap();
            reference.push((line, digest_of(&resp)));
        }
    }
    let clean_bytes = run_bytes(&clean.store_dir());
    assert!(clean_bytes > 0, "clean pass must have persisted entries");
    clean.finish();

    // A budget that holds roughly half the working set forces live
    // eviction while every single entry still fits individually.
    let budget = clean_bytes / 2 + 1;

    // ---- Pass 2: chaos. Budgeted store + seeded fault plan. ----
    // Faults are 0-based operation indices: the 2nd durable write hits
    // ENOSPC, the 2nd disk read hits EIO, the 2nd served response drops
    // its connection mid-flight, and every fsync stalls 2 ms.
    let plan = Arc::new(
        FaultPlan::parse("enospc_at=1,eio_read_at=1,drop_conn_at=1,slow_fsync_ms=2").unwrap(),
    );
    let plan_probe = Arc::clone(&plan);
    let chaos = TestServer::start("chaos", move |o| {
        o.fault = Some(plan);
        o.store_max_bytes = budget;
    });

    // Concurrent retrying clients, distinct jitter seeds, each cycling
    // the full point set twice (first cycle mixes cold/warm/dedup, the
    // second re-validates against the clients' remembered digests).
    let mut workers = Vec::new();
    for client_id in 0..3u64 {
        let socket = chaos.socket.clone();
        let reference = reference.clone();
        workers.push(std::thread::spawn(move || {
            let policy = RetryPolicy {
                max_retries: 6,
                base_ms: 2,
                cap_ms: 50,
                seed: 0xcaba_0000 + client_id,
            };
            let mut conn = Conn::new(&socket, policy);
            for _round in 0..2 {
                for (line, want) in &reference {
                    // `request` converging (not erroring) IS the retry
                    // assert; Conn itself re-checks digest bit-identity
                    // across its own retries and rounds.
                    let resp = conn.request(line).unwrap_or_else(|e| {
                        panic!("client {client_id} failed to converge: {e:#}")
                    });
                    assert_eq!(
                        &digest_of(&resp),
                        want,
                        "client {client_id}: faulted answer diverged from the clean run"
                    );
                }
            }
            conn.counters()
        }));
    }
    let mut attempts = 0u64;
    let mut retries = 0u64;
    let mut conn_errors = 0u64;
    for w in workers {
        let c = w.join().expect("client thread must not panic");
        attempts += c.attempts;
        retries += c.retries;
        conn_errors += c.conn_errors;
    }
    // 3 clients × 2 rounds × 4 points all converged.
    assert!(attempts >= 24, "every request must have been attempted");
    assert_eq!(
        plan_probe.injected(),
        3,
        "enospc, eio and drop_conn must each have fired exactly once"
    );
    // The dropped connection is the one fault a client *must* observe.
    assert!(conn_errors >= 1, "drop_conn_at never reached a client");
    assert!(retries >= 1, "the dropped connection must have been retried");

    // Budget held under fire — measured from disk, not the index.
    let disk = run_bytes(&chaos.store_dir());
    assert!(disk <= budget, "committed bytes {disk} exceed the budget {budget}");

    let summary = chaos.finish();
    let store = summary.store.expect("chaos daemon ran with a store");
    assert!(store.evicted >= 1, "a half-sized budget must have evicted at least once");
    assert_eq!(store.put_errors, 1, "the injected ENOSPC is counted, not fatal");
    assert_eq!(store.read_faults, 1, "the injected EIO is counted, not fatal");
    assert_eq!(summary.counters.job_errors, 0, "no fault may surface as a job error");
}

/// Brownout under deterministic pressure: a slow job pins the single
/// worker while more cold points pile up behind it, so the next worker
/// claim sees a queue wait far over the 1 ms threshold and engages the
/// controller. While backlog remains, new cold admissions shed with a
/// message naming brownout, warm hits keep flowing, and a retrying
/// client rides the sheds to a bit-identical `ok` after the idle-drain
/// exit.
#[test]
fn brownout_sheds_cold_serves_warm_and_recovers() {
    // Job 0 stalls 900 ms; jobs admitted behind it wait most of that.
    let plan = Arc::new(FaultPlan::parse("slow_at_job=0,slow_job_ms=900").unwrap());
    let ts = TestServer::start("brownout", move |o| {
        o.jobs = 1;
        o.fault = Some(plan);
        o.brownout_p95_ms = 1;
        o.brownout_min_samples = 1;
    });

    // Three cold points from three threads: the first claims the worker
    // and stalls, the other two queue behind it.
    let mut pressure = Vec::new();
    for (i, line) in points().into_iter().take(3).enumerate() {
        let socket = ts.socket.clone();
        pressure.push(std::thread::spawn(move || {
            let resp = caba::serve::client_request(&socket, &line).unwrap();
            assert!(resp.contains("\"status\":\"ok\""), "pressure point {i} failed: {resp}");
            resp
        }));
        // Admission order matters: point 0 must be the slow job.
        std::thread::sleep(Duration::from_millis(30));
    }

    // Wait for the controller to engage (the claim after the slow job
    // completes sees its ~900 ms queue wait). While the remaining
    // backlog drains, cold admissions must shed.
    let metrics = ts.handle.metrics().clone();
    let deadline = Instant::now() + Duration::from_secs(10);
    while metrics.brownout_entered.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "brownout never engaged");
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut brownout_sheds = 0;
    let mut probe = 0u32;
    while metrics.brownout_active.load(Ordering::Relaxed) == 1 && probe < 20 {
        // Distinct scale per probe → always a cold admission, never warm.
        let line = sweep_line("LPS", 0.011 + 0.001 * f64::from(probe));
        let resp = caba::serve::client_request(&ts.socket, &line).unwrap();
        if resp.contains("\"status\":\"shed\"") && resp.contains("brownout") {
            brownout_sheds += 1;
            break;
        }
        probe += 1;
    }
    assert!(brownout_sheds >= 1, "no cold admission shed while brownout was active");

    for p in pressure {
        p.join().expect("pressure client must not panic");
    }

    // Warm hits flow regardless of brownout state: the slow point is now
    // in the store, and repeats answer ok with the same digest.
    let mut conn = Conn::new(
        &ts.socket,
        RetryPolicy { max_retries: 10, base_ms: 5, cap_ms: 200, seed: 7 },
    );
    let first = points().remove(0);
    let a = digest_of(&conn.request(&first).unwrap());
    let b = digest_of(&conn.request(&first).unwrap());
    assert_eq!(a, b, "warm repeats must be bit-identical");

    // The shed probe point converges through the retrying client once
    // the queue drains (idle-drain exits the brownout).
    let probe_line = sweep_line("LPS", 0.011);
    let resp = conn.request(&probe_line).unwrap();
    assert!(resp.is_ok(), "retry must converge to ok, got {:?}", resp.raw());

    let summary = ts.finish();
    assert!(summary.counters.brownout_entered >= 1, "controller never engaged");
    assert!(summary.counters.brownout_shed >= 1, "brownout sheds must be counted");
    assert!(
        summary.counters.shed >= summary.counters.brownout_shed,
        "brownout sheds must be a subset of sheds"
    );
    assert!(
        summary.counters.brownout_exited >= 1,
        "idle-drain must have disengaged the controller by drain time"
    );
}
