//! The trace subsystem's contracts, end to end:
//!
//! 1. **Replay identity** — `trace record` followed by `trace replay`
//!    under the same config/design reproduces the original run's
//!    memory-side `SimStats` (and, same-design, its full timing)
//!    bit-identically.
//! 2. **Recording is non-invasive** — a recording run's stats equal an
//!    unrecorded run's, and recording the same run twice produces
//!    byte-identical files (deterministic format).
//! 3. **Cross-design replay** — a trace recorded under `Base` replays
//!    under `CABA-BDI` with exactly the stats of a direct `CABA-BDI` run
//!    (the payload-generator fallback is bit-faithful).
//! 4. **Sweep integration** — trace-driven jobs participate in cached
//!    sweeps keyed on the trace's content digest: re-running a matrix is
//!    pure cache hits, and re-loading the same file aliases correctly.
//! 5. **Loud failure** — bad magic, truncation and garbage never parse.

use caba::compress::Algo;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::sweep::{SweepEngine, SweepJob};
use caba::trace::{import, replay::TraceData, TraceKind};
use caba::workload::apps;
use caba::SimConfig;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn tiny_cfg() -> SimConfig {
    let mut c = SimConfig::default();
    c.n_sms = 2;
    c.max_cycles = 200_000;
    c
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("caba_trace_it_{}_{name}", std::process::id()))
}

fn record(app_name: &str, design: Design, path: &Path) -> caba::stats::SimStats {
    let app = apps::find(app_name).unwrap();
    let mut sim = Simulator::new(tiny_cfg(), design, app, 0.02);
    sim.record_to(path.to_str().unwrap()).expect("attach recorder");
    sim.run()
}

#[test]
fn record_then_replay_is_bit_identical() {
    let app = apps::find("PVC").unwrap();
    let design = Design::caba(Algo::Bdi);
    let baseline = Simulator::new(tiny_cfg(), design, app, 0.02).run();
    assert!(baseline.finished);

    let path = tmp("identity.cabatrace");
    let recorded = record("PVC", design, &path);

    // Recording must not perturb the simulation.
    assert_eq!(recorded.memory_signature(), baseline.memory_signature());
    assert_eq!(recorded.cycles, baseline.cycles);
    assert!(recorded.trace.accesses_recorded > 0, "no accesses captured");
    assert!(recorded.trace.payloads_recorded > 0, "no payloads captured");

    // The format is deterministic: recording the same run twice gives
    // byte-identical files (and therefore equal content digests).
    let path2 = tmp("identity2.cabatrace");
    record("PVC", design, &path2);
    assert_eq!(
        std::fs::read(&path).unwrap(),
        std::fs::read(&path2).unwrap(),
        "recording is not deterministic"
    );

    let trace = TraceData::load(path.to_str().unwrap()).expect("load trace");
    assert_eq!(trace.meta.kind, TraceKind::Recorded);
    assert_eq!(trace.meta.app, "PVC");
    assert_eq!(trace.meta.fingerprint, tiny_cfg().fingerprint());
    assert_eq!(trace.n_access_records, recorded.trace.accesses_recorded);

    // The acceptance contract: replayed memory-side stats are
    // bit-identical — and same-design replay reproduces full timing too.
    let replayed = Simulator::from_trace(tiny_cfg(), design, Arc::clone(&trace))
        .expect("build replay")
        .run();
    assert!(replayed.finished);
    assert_eq!(replayed.memory_signature(), baseline.memory_signature());
    assert_eq!(replayed.cycles, baseline.cycles);
    assert_eq!(replayed.warp_insts, baseline.warp_insts);
    assert_eq!(replayed.issue, baseline.issue);
    assert!(trace.replayed_accesses() > 0);

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&path2).ok();
}

#[test]
fn memo_record_then_replay_is_bit_identical() {
    // The §8.1 acceptance contract: a memo design's emergent LUT behaviour
    // (operand keys, install/evict order, hit counters) is a pure function
    // of the recorded workload, so trace replay reproduces the direct run
    // bit-identically — memory signature (which includes every memo
    // counter), cycles and issue breakdown.
    let app = apps::find("FRAG").unwrap();
    let design = Design::caba_memo();
    let direct = Simulator::new(tiny_cfg(), design, app, 0.02).run();
    assert!(direct.finished);
    assert!(direct.caba.memo_lookups > 0, "memo path never exercised");
    assert!(direct.caba.memo_hits > 0, "no emergent hits on a 70%-shared stream");

    let path = tmp("memo.cabatrace");
    let recorded = record("FRAG", design, &path);
    assert_eq!(recorded.memory_signature(), direct.memory_signature());

    let trace = TraceData::load(path.to_str().unwrap()).unwrap();
    let replayed = Simulator::from_trace(tiny_cfg(), design, Arc::clone(&trace))
        .expect("build memo replay")
        .run();
    assert!(replayed.finished);
    assert_eq!(replayed.memory_signature(), direct.memory_signature());
    assert_eq!(replayed.cycles, direct.cycles);
    assert_eq!(replayed.issue, direct.issue);
    assert_eq!(replayed.caba.memo_hits, direct.caba.memo_hits);
    assert_eq!(replayed.caba.memo_evictions, direct.caba.memo_evictions);

    // Cross-design over the same trace: the hybrid must also replay
    // deterministically (twice → identical stats).
    let hybrid = Design::caba_memo_hybrid();
    let a = Simulator::from_trace(tiny_cfg(), hybrid, Arc::clone(&trace)).unwrap().run();
    let b = Simulator::from_trace(tiny_cfg(), hybrid, Arc::clone(&trace)).unwrap().run();
    assert_eq!(a, b);
    assert!(a.caba.memo_lookups > 0);

    std::fs::remove_file(&path).ok();
}

#[test]
fn cross_design_replay_matches_direct_run() {
    // Record under Base (no compression → no payloads are even sampled),
    // replay under CABA-BDI: the generator fallback must reproduce the
    // exact data a direct CABA-BDI run generates.
    let app = apps::find("PVC").unwrap();
    let path = tmp("cross.cabatrace");
    let recorded = record("PVC", Design::base(), &path);
    assert_eq!(recorded.trace.payloads_recorded, 0, "Base run should sample no payloads");

    let trace = TraceData::load(path.to_str().unwrap()).unwrap();
    let caba_design = Design::caba(Algo::Bdi);
    let direct = Simulator::new(tiny_cfg(), caba_design, app, 0.02).run();
    let replayed = Simulator::from_trace(tiny_cfg(), caba_design, Arc::clone(&trace))
        .unwrap()
        .run();
    assert_eq!(replayed.memory_signature(), direct.memory_signature());
    assert_eq!(replayed.cycles, direct.cycles);
    assert!(trace.payload_fallbacks_count() > 0, "fallback path never exercised");

    std::fs::remove_file(&path).ok();
}

#[test]
fn trace_jobs_sweep_with_cache_hits() {
    let path = tmp("sweep.cabatrace");
    record("PVC", Design::caba(Algo::Bdi), &path);
    let trace = TraceData::load(path.to_str().unwrap()).unwrap();

    let engine = SweepEngine::new(2);
    let mut matrix = Vec::new();
    for design in [Design::base(), Design::caba(Algo::Bdi)] {
        for bw in [0.5, 1.0] {
            let mut cfg = tiny_cfg();
            cfg.bw_scale = bw;
            matrix.push(SweepJob::replay(&trace, design, cfg));
        }
    }
    let first = engine.run(&matrix).unwrap();
    let entries = engine.cache_entries();
    assert_eq!(entries, 4, "4 distinct trace-driven points expected");

    // Re-running the matrix must be pure cache hits.
    let second = engine.run(&matrix).unwrap();
    assert_eq!(first, second);
    assert_eq!(engine.cache_entries(), entries, "re-run executed new simulations");

    // Re-loading the same file (a different Arc, same content digest)
    // must alias into the same cache entries.
    let reloaded = TraceData::load(path.to_str().unwrap()).unwrap();
    assert_eq!(reloaded.digest, trace.digest);
    let via_reload = engine.run_one(&SweepJob::replay(&reloaded, Design::base(), {
        let mut c = tiny_cfg();
        c.bw_scale = 0.5;
        c
    }));
    assert_eq!(via_reload, first[0]);
    assert_eq!(engine.cache_entries(), entries, "reloaded trace missed the cache");

    // Replay must differ across designs (the sweep is measuring something).
    assert_ne!(first[0], first[2], "Base and CABA-BDI replays identical?");

    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_run_trace_replays_without_panicking() {
    // Record under a cycle budget the run cannot finish in, then replay
    // under a design/bandwidth where the simulation progresses *further*
    // than the recording did: misses past the recording horizon must
    // yield empty accesses, not panics (the sweep-over-trace use case).
    let app = apps::find("PVC").unwrap();
    let mut cfg = tiny_cfg();
    cfg.max_cycles = 3_000; // far too small to drain
    let path = tmp("partial.cabatrace");
    let mut sim = Simulator::new(cfg.clone(), Design::base(), app, 0.02);
    sim.record_to(path.to_str().unwrap()).unwrap();
    let recorded = sim.run();
    assert!(!recorded.finished, "budget was supposed to truncate the run");

    let trace = TraceData::load(path.to_str().unwrap()).unwrap();
    assert!(!trace.complete, "trailer must mark the run as truncated");

    // Full budget + a different design: runs past the recording horizon.
    let replayed = Simulator::from_trace(tiny_cfg(), Design::caba(Algo::Bdi), Arc::clone(&trace))
        .unwrap()
        .run();
    assert!(replayed.warp_insts > 0);

    // A second recorder on the same simulator must be refused, not
    // silently swapped in (it would abandon a half-written file).
    let mut sim2 = Simulator::new(cfg, Design::base(), app, 0.02);
    sim2.record_to(path.to_str().unwrap()).unwrap();
    assert!(sim2.record_to(path.to_str().unwrap()).is_err());

    std::fs::remove_file(&path).ok();
}

#[test]
fn imported_text_trace_drives_the_pipeline() {
    // A synthetic accelsim-style dump: streaming loads plus periodic
    // stores over a ~200-line footprint.
    let mut txt = String::from("# synthetic dump\n");
    for i in 0u64..300 {
        let addr = 0x10000 + (i % 64) * 128 + (i / 64) * 4096;
        if i % 3 == 0 {
            txt.push_str(&format!("st 0x{addr:x} 128\n"));
        } else {
            txt.push_str(&format!("ld 0x{addr:x} 128 0xffffffff\n"));
        }
    }
    let txt_path = tmp("dump.txt");
    let trc_path = tmp("dump.cabatrace");
    std::fs::write(&txt_path, &txt).unwrap();

    let trace =
        import::import_file(txt_path.to_str().unwrap(), trc_path.to_str().unwrap(), "lowdyn")
            .expect("import");
    assert_eq!(trace.meta.kind, TraceKind::Imported);
    assert_eq!(trace.n_loads + trace.n_stores, 300);
    let info = caba::report::trace_summary(&trace);
    assert!(info.contains("imported text dump"), "{info}");

    let stats = Simulator::from_trace(tiny_cfg(), Design::caba(Algo::Bdi), Arc::clone(&trace))
        .expect("replay imported")
        .run();
    assert!(stats.finished, "imported replay did not drain");
    assert!(stats.warp_insts > 0);
    assert!(stats.l1.accesses > 0, "no memory traffic from the trace");
    assert!(stats.dram.bursts > 0);
    // lowdyn data is compressible; the pipeline must see that.
    assert!(
        stats.dram.compression_ratio() > 1.0,
        "ratio={}",
        stats.dram.compression_ratio()
    );

    // Determinism end to end.
    let again = Simulator::from_trace(tiny_cfg(), Design::caba(Algo::Bdi), Arc::clone(&trace))
        .unwrap()
        .run();
    assert_eq!(stats, again);

    std::fs::remove_file(&txt_path).ok();
    std::fs::remove_file(&trc_path).ok();
}

#[test]
fn corrupt_traces_fail_loudly() {
    // Not a trace at all.
    let junk = tmp("junk.cabatrace");
    std::fs::write(&junk, b"definitely not a trace file").unwrap();
    let err = TraceData::load(junk.to_str().unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("bad magic"), "{err:#}");

    // A real trace, truncated at many offsets: every prefix must error.
    let path = tmp("trunc.cabatrace");
    record("PVC", Design::caba(Algo::Bdi), &path);
    let bytes = std::fs::read(&path).unwrap();
    assert!(TraceData::from_bytes(&bytes).is_ok());
    for cut in [4, 17, 40, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        assert!(
            TraceData::from_bytes(&bytes[..cut]).is_err(),
            "truncation at {cut}/{} parsed successfully",
            bytes.len()
        );
    }

    std::fs::remove_file(&junk).ok();
    std::fs::remove_file(&path).ok();
}
