//! Dedicated unit + property tests for the flat per-access tables behind
//! the SM hot path (`caba::core::tables`): the open-addressed [`MshrTable`]
//! and the dense generation-stamped [`ReleaseTable`]. PR 5 shipped these
//! with in-module smoke tests only; this file pins the parts the sharded
//! tick leans on — growth policy (resize *before* 3/4 occupancy, never
//! mid-probe), the rebuild-on-sweep invariant, `next_fill_after`'s
//! strictly-future precision, and stale-uid release dropping — plus
//! model-based properties against `std::collections::HashMap` references.

use caba::core::tables::{MshrInfo, MshrTable, ReleaseTable};
use caba::prop_assert;
use caba::util::miniprop::{default_cases, forall};
use std::collections::HashMap;

// ---------------------------------------------------------------------------
// MshrTable: growth policy
// ---------------------------------------------------------------------------

#[test]
fn mshr_initial_sizing_gives_2x_headroom() {
    // slots = next_pow2(2 * (limit + max_lines)), floored at 16.
    assert_eq!(MshrTable::new(4, 4).capacity_slots(), 16);
    assert_eq!(MshrTable::new(2, 2).capacity_slots(), 16); // floor
    assert_eq!(MshrTable::new(64, 32).capacity_slots(), 256);
    assert_eq!(MshrTable::new(0, 0).capacity_slots(), 16); // floor again
}

#[test]
fn mshr_grows_exactly_at_three_quarters() {
    // Capacity 16 → the grow check `(len+1)*4 > slots*3` first trips when
    // inserting the 13th entry (13*4 = 52 > 48): 12 entries fit at 16
    // slots, the 13th doubles to 32 *before* probing for a slot.
    let mut t = MshrTable::new(4, 4);
    for i in 0..12u64 {
        t.insert(i, MshrInfo { fill_at: i, awc_token: None });
        assert_eq!(t.capacity_slots(), 16, "insert {i} must not grow yet");
    }
    assert_eq!(t.len(), 12);
    t.insert(12, MshrInfo { fill_at: 12, awc_token: None });
    assert_eq!(t.capacity_slots(), 32, "13th insert crosses 3/4 of 16");
    assert_eq!(t.len(), 13);
    // Next doubling: (len+1)*4 > 96 ⇒ at the 25th insert.
    for i in 13..24u64 {
        t.insert(i, MshrInfo { fill_at: i, awc_token: None });
        assert_eq!(t.capacity_slots(), 32, "insert {i} must not grow yet");
    }
    t.insert(24, MshrInfo { fill_at: 24, awc_token: None });
    assert_eq!(t.capacity_slots(), 64, "25th insert crosses 3/4 of 32");
    // Growth preserved every entry.
    for i in 0..25u64 {
        assert_eq!(t.get(i).expect("entry survived growth").fill_at, i);
    }
}

#[test]
fn mshr_sweep_rebuilds_in_place_without_growing() {
    let mut t = MshrTable::new(4, 4);
    for i in 0..12u64 {
        t.insert(i, MshrInfo { fill_at: 10 * i, awc_token: (i % 3 == 0).then_some(i) });
    }
    let cap = t.capacity_slots();
    t.sweep(|info| info.fill_at >= 60);
    // The sweep rebuild reuses the same physical array: same capacity,
    // tombstone-free, survivors fully probe-able.
    assert_eq!(t.capacity_slots(), cap, "sweep must not resize");
    assert_eq!(t.len(), 6);
    for i in 0..12u64 {
        if 10 * i >= 60 {
            let info = t.get(i).expect("survivor present");
            assert_eq!(info.fill_at, 10 * i);
            assert_eq!(info.awc_token, (i % 3 == 0).then_some(i));
        } else {
            assert!(!t.contains_key(i), "swept entry {i} still visible");
        }
    }
    // Swept slots are genuinely vacant: refill to the same occupancy
    // without triggering growth.
    for i in 100..106u64 {
        t.insert(i, MshrInfo { fill_at: i, awc_token: None });
    }
    assert_eq!(t.len(), 12);
    assert_eq!(t.capacity_slots(), cap);
}

#[test]
fn mshr_next_fill_after_is_strictly_future_and_exact() {
    let mut t = MshrTable::new(4, 4);
    for (line, fill_at) in [(1u64, 5u64), (2, 10), (3, 10), (4, 17)] {
        t.insert(line, MshrInfo { fill_at, awc_token: None });
    }
    // Strictly greater than `now` — a fill *at* now is not a future wake.
    assert_eq!(t.next_fill_after(0), 5);
    assert_eq!(t.next_fill_after(4), 5);
    assert_eq!(t.next_fill_after(5), 10);
    assert_eq!(t.next_fill_after(9), 10);
    assert_eq!(t.next_fill_after(10), 17);
    assert_eq!(t.next_fill_after(16), 17);
    assert_eq!(t.next_fill_after(17), u64::MAX);
    assert_eq!(MshrTable::new(4, 4).next_fill_after(0), u64::MAX);
}

// ---------------------------------------------------------------------------
// MshrTable: model-based property vs. a HashMap reference
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum MshrOp {
    Insert { line: u64, fill_at: u64, token: Option<u64> },
    Sweep { threshold: u64 },
    Query { line: u64 },
    NextFill { now: u64 },
}

#[test]
fn prop_mshr_matches_hashmap_model() {
    // Any op sequence (inserts over a small line space to force probe
    // clusters, full-rebuild sweeps, point queries, wake queries) leaves
    // the open-addressed table observationally equal to a HashMap.
    forall(
        "mshr_matches_hashmap_model",
        default_cases(),
        |r| {
            let n = 20 + r.range(0, 120);
            (0..n)
                .map(|_| match r.below(10) {
                    0..=4 => MshrOp::Insert {
                        line: r.below(64),
                        fill_at: r.below(200),
                        token: r.chance(0.3).then(|| r.below(8)),
                    },
                    5 => MshrOp::Sweep { threshold: r.below(200) },
                    6..=7 => MshrOp::Query { line: r.below(64) },
                    _ => MshrOp::NextFill { now: r.below(220) },
                })
                .collect::<Vec<_>>()
        },
        |ops| {
            let mut t = MshrTable::new(4, 4);
            let mut model: HashMap<u64, (u64, Option<u64>)> = HashMap::new();
            for (i, op) in ops.iter().enumerate() {
                match *op {
                    MshrOp::Insert { line, fill_at, token } => {
                        // Callers never double-insert (they merge on `get`
                        // first); mirror that contract here.
                        if model.contains_key(&line) {
                            continue;
                        }
                        model.insert(line, (fill_at, token));
                        t.insert(line, MshrInfo { fill_at, awc_token: token });
                    }
                    MshrOp::Sweep { threshold } => {
                        model.retain(|_, &mut (fill_at, _)| fill_at >= threshold);
                        t.sweep(|info| info.fill_at >= threshold);
                    }
                    MshrOp::Query { line } => {
                        let got = t.get(line).map(|i| (i.fill_at, i.awc_token));
                        let want = model.get(&line).copied();
                        prop_assert!(
                            got == want,
                            "op {i}: get({line}) = {got:?}, model says {want:?}"
                        );
                    }
                    MshrOp::NextFill { now } => {
                        let want = model
                            .values()
                            .filter(|&&(fill_at, _)| fill_at > now)
                            .map(|&(fill_at, _)| fill_at)
                            .min()
                            .unwrap_or(u64::MAX);
                        let got = t.next_fill_after(now);
                        prop_assert!(
                            got == want,
                            "op {i}: next_fill_after({now}) = {got}, model says {want}"
                        );
                    }
                }
                prop_assert!(
                    t.len() == model.len(),
                    "op {i}: len {} != model {}",
                    t.len(),
                    model.len()
                );
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// ReleaseTable: generation stamping
// ---------------------------------------------------------------------------

#[test]
fn release_stale_uid_on_recycled_warp_slot_is_dropped() {
    // The scenario the stamp exists for: warp slot 3 runs CTA A's warp
    // (uid 100), opens a 2-part release, retires mid-flight, and the slot
    // is re-tenanted by CTA B's warp (uid 200) which opens its own
    // release. A's late retirements must neither complete nor corrupt
    // B's release.
    let mut r = ReleaseTable::new(8);
    r.insert(3, 7, 100, 2, 0);
    assert_eq!(r.release(3, 7, 100, 40), None); // part 1 of A
    r.insert(3, 7, 200, 2, 10); // slot recycled: B overwrites
    assert_eq!(r.release(3, 7, 100, 55), None, "stale A retirement dropped");
    assert_eq!(r.release(3, 7, 200, 50), None); // part 1 of B — still open
    assert_eq!(r.release(3, 7, 100, 60), None, "second stale A retirement dropped");
    // B completes with its own floor (max of insert floor and part times).
    assert_eq!(r.release(3, 7, 200, 45), Some(50));
    // Freed: even the rightful uid gets nothing afterwards.
    assert_eq!(r.release(3, 7, 200, 70), None);
}

#[test]
fn release_slots_are_independent_per_warp_and_reg() {
    let mut r = ReleaseTable::new(4);
    r.insert(0, 1, 11, 1, 5);
    r.insert(0, 2, 11, 1, 6);
    r.insert(1, 1, 22, 1, 7);
    assert_eq!(r.release(1, 1, 22, 9), Some(9));
    assert_eq!(r.release(0, 2, 11, 3), Some(6));
    assert_eq!(r.release(0, 1, 11, 8), Some(8));
}

#[derive(Debug, Clone, Copy)]
enum RelOp {
    Insert { warp: usize, reg: u8, uid: u64, parts: u32, floor: u64 },
    Release { warp: usize, reg: u8, uid: u64, at: u64 },
}

#[test]
fn prop_release_matches_hashmap_model() {
    // Uids drawn from a tiny space so stale-uid releases happen often;
    // warps/regs from a tiny space so slots get recycled constantly.
    forall(
        "release_matches_hashmap_model",
        default_cases(),
        |r| {
            let n = 20 + r.range(0, 120);
            (0..n)
                .map(|_| {
                    let warp = r.range(0, 4);
                    let reg = r.below(3) as u8;
                    let uid = 1 + r.below(4);
                    if r.chance(0.35) {
                        RelOp::Insert { warp, reg, uid, parts: 1 + r.below(3) as u32, floor: r.below(100) }
                    } else {
                        RelOp::Release { warp, reg, uid, at: r.below(100) }
                    }
                })
                .collect::<Vec<_>>()
        },
        |ops| {
            let mut t = ReleaseTable::new(4);
            // model: (warp, reg) → (parts, floor, uid)
            let mut model: HashMap<(usize, u8), (u32, u64, u64)> = HashMap::new();
            for (i, op) in ops.iter().enumerate() {
                match *op {
                    RelOp::Insert { warp, reg, uid, parts, floor } => {
                        model.insert((warp, reg), (parts, floor, uid));
                        t.insert(warp, reg, uid, parts, floor);
                    }
                    RelOp::Release { warp, reg, uid, at } => {
                        let want = match model.get_mut(&(warp, reg)) {
                            Some(slot) if slot.2 == uid => {
                                slot.0 -= 1;
                                slot.1 = slot.1.max(at);
                                if slot.0 == 0 {
                                    let floor = slot.1;
                                    model.remove(&(warp, reg));
                                    Some(floor)
                                } else {
                                    None
                                }
                            }
                            _ => None,
                        };
                        let got = t.release(warp, reg, uid, at);
                        prop_assert!(
                            got == want,
                            "op {i}: release({warp},{reg},uid={uid},at={at}) = {got:?}, model says {want:?}"
                        );
                    }
                }
            }
            Ok(())
        },
    );
}
