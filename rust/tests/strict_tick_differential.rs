//! The event-driven tick's equivalence contract, end to end:
//!
//! `Simulator::run` skips any SM whose `next_event` lies in the future and
//! bulk-charges its stall cycles on wake; `strict_tick=true` forces the
//! naive reference (every SM, every cycle, no fast-forward). The two paths
//! must be **bit-identical** — not "statistically close":
//!
//! 1. across apps × designs (memory-bound compression, compute-bound
//!    memoization, hybrid, prefetch, hardware-compression), on cycles,
//!    warp_insts, the *full* issue-cycle breakdown (category for
//!    category, not just the total), and `memory_signature()`;
//! 2. through trace record → replay (a trace recorded under one tick mode
//!    replays bit-identically under the other);
//! 3. at the unit level: a single hand-built core, driven per-cycle vs.
//!    skip-and-settle over the same workload, lands on the identical
//!    `IssueBreakdown`;
//! 4. under a mid-stall cycle-budget cut (settlement on the `max_cycles`
//!    exit path charges exactly the strict count).
//!
//! The issue-slot conservation law `issue.total() == cycles ×
//! schedulers_per_sm × n_sms` is asserted throughout (and again as a
//! `debug_assert` inside `Simulator::collect`).

use caba::compress::Algo;
use caba::core::{Core, CycleCtx};
use caba::mem::MemSystem;
use caba::memo::MemoGeometry;
use caba::sim::designs::Design;
use caba::sim::{DataModel, Simulator};
use caba::trace::replay::TraceData;
use caba::workload::{apps, Workload};
use caba::SimConfig;
use std::sync::Arc;

fn cfg(strict: bool) -> SimConfig {
    let mut c = SimConfig::default();
    c.n_sms = 2;
    c.max_cycles = 500_000;
    c.strict_tick = strict;
    c
}

fn run_pair(app_name: &str, design: Design, scale: f64, base: &SimConfig) {
    let app = apps::find(app_name).expect("differential app exists");
    let mut event_cfg = base.clone();
    event_cfg.strict_tick = false;
    let mut strict_cfg = base.clone();
    strict_cfg.strict_tick = true;
    let event = Simulator::new(event_cfg, design, app, scale).run();
    let strict = Simulator::new(strict_cfg, design, app, scale).run();

    let label = format!("{app_name}/{}", design.name);
    assert_eq!(event.finished, strict.finished, "{label}: finished");
    assert_eq!(event.cycles, strict.cycles, "{label}: cycles");
    assert_eq!(event.warp_insts, strict.warp_insts, "{label}: warp_insts");
    assert_eq!(event.ctas_launched, strict.ctas_launched, "{label}: ctas");
    // Full per-category breakdown — the bulk-charged classification must
    // reproduce the per-cycle Fig. 2 taxonomy exactly, which subsumes the
    // issue.total() requirement.
    assert_eq!(event.issue, strict.issue, "{label}: issue breakdown");
    assert_eq!(
        event.issue.total(),
        event.cycles * (base.schedulers_per_sm * base.n_sms) as u64,
        "{label}: issue slots not conserved"
    );
    assert_eq!(
        event.memory_signature(),
        strict.memory_signature(),
        "{label}: memory signature"
    );
}

#[test]
fn strict_equals_event_across_apps_and_designs() {
    // Memory-bound × compression (the paper's core), compute-bound ×
    // memoization (§8.1), the hybrid, prefetching (§8.2), hardware
    // compression, and the plain baseline.
    let pairs: &[(&str, Design)] = &[
        ("SLA", Design::base()),
        ("PVC", Design::caba(Algo::Bdi)),
        ("MM", Design::caba(Algo::Fpc)),
        ("PVC", Design::hw_bdi()),
        ("SLA", Design::caba_prefetch()),
        ("FRAG", Design::caba_memo()),
        ("NNA", Design::caba_memo_hybrid()),
    ];
    for &(app, design) in pairs {
        run_pair(app, design, 0.02, &cfg(false));
    }
}

#[test]
fn strict_equals_event_with_four_schedulers() {
    // schedulers_per_sm used to be hard-coded to 2 in the scheduler
    // structures (`--set schedulers_per_sm=4` indexed out of bounds); this
    // pins both the fix and the differential at the wider width.
    let mut base = cfg(false);
    base.schedulers_per_sm = 4;
    run_pair("PVC", Design::caba(Algo::Bdi), 0.02, &base);
    run_pair("FRAG", Design::caba_memo(), 0.02, &base);
}

#[test]
fn strict_equals_event_on_trace_replay() {
    // Record under the event-driven tick, then replay under both modes:
    // the trace-driven workload must behave identically too (record →
    // replay bit-identity is mode-independent).
    let app = apps::find("PVC").unwrap();
    let design = Design::caba(Algo::Bdi);
    let path = std::env::temp_dir().join(format!(
        "caba_strict_diff_{}.cabatrace",
        std::process::id()
    ));
    let recorded = {
        let mut sim = Simulator::new(cfg(false), design, app, 0.02);
        sim.record_to(path.to_str().unwrap()).expect("attach recorder");
        sim.run()
    };
    assert!(recorded.finished);

    let trace = TraceData::load(path.to_str().unwrap()).expect("load trace");
    let event = Simulator::from_trace(cfg(false), design, Arc::clone(&trace))
        .expect("event replay")
        .run();
    let strict = Simulator::from_trace(cfg(true), design, Arc::clone(&trace))
        .expect("strict replay")
        .run();
    assert_eq!(event.cycles, strict.cycles);
    assert_eq!(event.warp_insts, strict.warp_insts);
    assert_eq!(event.issue, strict.issue);
    assert_eq!(event.memory_signature(), strict.memory_signature());
    // And both reproduce the recording run's memory side.
    assert_eq!(event.memory_signature(), recorded.memory_signature());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn strict_equals_event_under_cycle_budget_cut() {
    // Cut the budget mid-flight (including, almost surely, mid-stall for
    // the memory-bound app): the settlement on the max_cycles exit path
    // must charge exactly what strict per-cycle ticking charges, and both
    // must report cycles == max_cycles.
    let mut saw_cut = false;
    for budget in [1_000u64, 7_777, 20_011] {
        let mut base = cfg(false);
        base.max_cycles = budget;
        let app = apps::find("PVC").unwrap();
        let design = Design::caba(Algo::Bdi);
        let mut strict_cfg = base.clone();
        strict_cfg.strict_tick = true;
        let event = Simulator::new(base, design, app, 0.05).run();
        let strict = Simulator::new(strict_cfg, design, app, 0.05).run();
        assert_eq!(event.finished, strict.finished, "budget {budget}");
        assert_eq!(event.cycles, strict.cycles, "budget {budget}");
        if !event.finished {
            // A budget-cut run must stop at exactly the budget in both
            // modes (the event path clamps its fast-forward jumps).
            saw_cut = true;
            assert_eq!(event.cycles, budget, "budget {budget}");
        }
        assert_eq!(event.warp_insts, strict.warp_insts, "budget {budget}");
        assert_eq!(event.issue, strict.issue, "budget {budget}");
        assert_eq!(
            event.memory_signature(),
            strict.memory_signature(),
            "budget {budget}"
        );
    }
    assert!(saw_cut, "no budget actually cut the run mid-flight — shrink the budgets");
}

/// Drive one hand-built core through `Core::cycle` per-cycle vs.
/// skip-and-settle, with identical surroundings, and require the identical
/// issue breakdown — the unit-level form of the bulk-charge contract.
fn handbuilt_core_differential(app_name: &str, design: Design, horizon: u64) {
    let cfg = SimConfig::default();
    let app = apps::find(app_name).unwrap();
    let wl = Workload::build(app, &cfg, 0.01);
    let geom = MemoGeometry::for_workload(&cfg, &design, &wl);

    let run = |event: bool| {
        let mut core = Core::new(0, &cfg, &design, &geom);
        let mut mem = MemSystem::new(&cfg, &design);
        let mut data = DataModel::new(
            Box::new(caba::compress::oracle::MemoOracle::new(
                caba::compress::oracle::NativeOracle,
            )),
            &wl.arrays,
        );
        let mut stats = caba::stats::SimStats::default();
        core.launch_cta(0, 0, &wl);
        let mut t = 0u64;
        while t < horizon {
            if event && core.next_event > t {
                // Jump straight to the wake (clamped to the horizon); the
                // skipped window settles inside the next cycle() call or
                // the final settle_to below.
                t = core.next_event.min(horizon);
                continue;
            }
            let mut ctx = CycleCtx {
                cfg: &cfg,
                design: &design,
                wl: &wl,
                mem: &mut mem,
                data: &mut data,
                stats: &mut stats,
            };
            core.cycle(t, &mut ctx);
            t += 1;
        }
        core.settle_to(horizon, &cfg, &design);
        core.issue
    };

    let per_cycle = run(false);
    let skipped = run(true);
    assert_eq!(
        skipped, per_cycle,
        "{app_name}/{}: bulk-charged breakdown diverged from per-cycle",
        design.name
    );
    assert_eq!(
        per_cycle.total(),
        horizon * cfg.schedulers_per_sm as u64,
        "{app_name}/{}: hand-built core lost scheduler slots",
        design.name
    );
    // The scenario must actually exercise stalls, or the test is vacuous.
    assert!(
        per_cycle.total() > per_cycle.active,
        "{app_name}/{}: no stall cycles in the hand-built scenario",
        design.name
    );
}

#[test]
fn bulk_charged_stalls_match_per_cycle_on_handbuilt_core() {
    // Memory-structural + data-dependence windows (long DRAM stalls).
    handbuilt_core_differential("PVC", Design::caba(Algo::Bdi), 20_000);
    // Compute-structural windows (busy quarter-rate SFU pipes) and the
    // memo lookup/install machinery.
    handbuilt_core_differential("FRAG", Design::caba_memo(), 20_000);
    // Plain baseline.
    handbuilt_core_differential("SLA", Design::base(), 20_000);
}
