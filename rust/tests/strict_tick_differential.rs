//! The tick loop's equivalence contract, end to end — now **three-way**:
//!
//! * `strict_tick=true` — the naive reference: every SM, every cycle, no
//!   fast-forward.
//! * event-serial (`sim_threads=1`) — `Simulator::run` skips any SM whose
//!   `next_event` lies in the future and bulk-charges its stall cycles on
//!   wake.
//! * event-sharded (`sim_threads=N`) — cores advance independently on a
//!   scoped thread pool between memory-system epochs, then rendezvous to
//!   drain the shared `MemSystem` in deterministic SM order.
//!
//! All three must be **bit-identical** — not "statistically close":
//!
//! 1. across apps × designs (memory-bound compression, compute-bound
//!    memoization, hybrid, prefetch, hardware-compression), on cycles,
//!    warp_insts, the *full* issue-cycle breakdown (category for
//!    category, not just the total), and `memory_signature()`, at every
//!    thread count in {1, 2, 4, 8};
//! 2. through trace record → replay (a trace recorded under one tick mode
//!    replays bit-identically under every other mode and thread count);
//! 3. at the unit level: a single hand-built core, driven per-cycle vs.
//!    skip-and-settle through the two-phase `cycle()`/`drain()` protocol,
//!    lands on the identical `IssueBreakdown`;
//! 4. under a mid-stall cycle-budget cut (settlement on the `max_cycles`
//!    exit path charges exactly the strict count in every mode).
//!
//! 5. with the flight recorder on: the full `TelemetryRun` — every chip
//!    and per-SM window delta, occupancy sample and assist-warp span —
//!    is bit-identical across all three modes (at a window cadence chosen
//!    to land boundaries mid-fast-forward), and turning the recorder on
//!    leaves `SimStats` and the config fingerprint untouched.
//!
//! The issue-slot conservation law `issue.total() == cycles ×
//! schedulers_per_sm × n_sms` is asserted throughout (and again as a
//! `debug_assert` inside `Simulator::collect`).

use caba::compress::Algo;
use caba::core::{Core, CoreCtx, DrainCtx};
use caba::mem::MemSystem;
use caba::memo::MemoGeometry;
use caba::sim::designs::Design;
use caba::sim::{DataModel, Simulator};
use caba::stats::SimStats;
use caba::trace::replay::TraceData;
use caba::workload::{apps, Workload};
use caba::SimConfig;
use std::sync::Arc;

/// Thread counts for the sharded leg. `effective_threads` clamps to
/// `n_sms`, so the base config below uses `n_sms = 8` — each count here
/// then exercises a genuinely different core partition (8/4/1 cores per
/// chunk) instead of collapsing to the same one.
const THREADS: [usize; 3] = [2, 4, 8];

fn cfg(strict: bool) -> SimConfig {
    let mut c = SimConfig::default();
    c.n_sms = 8;
    c.max_cycles = 500_000;
    c.strict_tick = strict;
    c
}

/// Run one app×design point under all modes — strict, event-serial, and
/// event-sharded at every [`THREADS`] count — and require bit-identity
/// against the strict reference on every golden stat.
fn run_matrix(app_name: &str, design: Design, scale: f64, base: &SimConfig) {
    let app = apps::find(app_name).expect("differential app exists");
    let run_mode = |strict: bool, threads: usize| -> SimStats {
        let mut c = base.clone();
        c.strict_tick = strict;
        c.sim_threads = threads;
        Simulator::new(c, design, app, scale).run()
    };
    let strict = run_mode(true, 1);
    assert_eq!(
        strict.issue.total(),
        strict.cycles * (base.schedulers_per_sm * base.n_sms) as u64,
        "{app_name}/{}: issue slots not conserved",
        design.name
    );

    let check = |mode: &str, got: &SimStats| {
        let label = format!("{app_name}/{} [{mode} vs strict]", design.name);
        assert_eq!(got.finished, strict.finished, "{label}: finished");
        assert_eq!(got.cycles, strict.cycles, "{label}: cycles");
        assert_eq!(got.warp_insts, strict.warp_insts, "{label}: warp_insts");
        assert_eq!(got.ctas_launched, strict.ctas_launched, "{label}: ctas");
        // Full per-category breakdown — the bulk-charged classification
        // must reproduce the per-cycle Fig. 2 taxonomy exactly, which
        // subsumes the issue.total() requirement.
        assert_eq!(got.issue, strict.issue, "{label}: issue breakdown");
        assert_eq!(
            got.memory_signature(),
            strict.memory_signature(),
            "{label}: memory signature"
        );
    };

    check("event-serial", &run_mode(false, 1));
    for &threads in &THREADS {
        check(&format!("sharded x{threads}"), &run_mode(false, threads));
    }
}

#[test]
fn strict_equals_event_across_apps_and_designs() {
    // Memory-bound × compression (the paper's core), compute-bound ×
    // memoization (§8.1), the hybrid, prefetching (§8.2), hardware
    // compression, and the plain baseline.
    let pairs: &[(&str, Design)] = &[
        ("SLA", Design::base()),
        ("PVC", Design::caba(Algo::Bdi)),
        ("MM", Design::caba(Algo::Fpc)),
        ("PVC", Design::hw_bdi()),
        ("SLA", Design::caba_prefetch()),
        ("FRAG", Design::caba_memo()),
        ("NNA", Design::caba_memo_hybrid()),
    ];
    for &(app, design) in pairs {
        run_matrix(app, design, 0.02, &cfg(false));
    }
}

#[test]
fn strict_equals_event_with_four_schedulers() {
    // schedulers_per_sm used to be hard-coded to 2 in the scheduler
    // structures (`--set schedulers_per_sm=4` indexed out of bounds); this
    // pins both the fix and the differential at the wider width.
    let mut base = cfg(false);
    base.schedulers_per_sm = 4;
    run_matrix("PVC", Design::caba(Algo::Bdi), 0.02, &base);
    run_matrix("FRAG", Design::caba_memo(), 0.02, &base);
}

#[test]
fn strict_equals_event_on_trace_replay() {
    // Record under the event-driven serial tick (recording pins
    // `effective_threads` to 1 — emission order is part of the file
    // format), then replay under every mode: strict, event-serial, and
    // sharded at each thread count. The trace-driven workload must behave
    // identically everywhere, and all replays must reproduce the
    // recording run's memory side.
    let app = apps::find("PVC").unwrap();
    let design = Design::caba(Algo::Bdi);
    let path = std::env::temp_dir().join(format!(
        "caba_strict_diff_{}.cabatrace",
        std::process::id()
    ));
    let recorded = {
        let mut sim = Simulator::new(cfg(false), design, app, 0.02);
        sim.record_to(path.to_str().unwrap()).expect("attach recorder");
        sim.run()
    };
    assert!(recorded.finished);

    let trace = TraceData::load(path.to_str().unwrap()).expect("load trace");
    let replay = |strict: bool, threads: usize| -> SimStats {
        let mut c = cfg(strict);
        c.sim_threads = threads;
        Simulator::from_trace(c, design, Arc::clone(&trace))
            .expect("replay sim")
            .run()
    };
    let strict = replay(true, 1);
    let mut runs = vec![("event-serial".to_string(), replay(false, 1))];
    for &threads in &THREADS {
        runs.push((format!("sharded x{threads}"), replay(false, threads)));
    }
    for (mode, got) in &runs {
        assert_eq!(got.cycles, strict.cycles, "replay {mode}: cycles");
        assert_eq!(got.warp_insts, strict.warp_insts, "replay {mode}: warp_insts");
        assert_eq!(got.issue, strict.issue, "replay {mode}: issue breakdown");
        assert_eq!(
            got.memory_signature(),
            strict.memory_signature(),
            "replay {mode}: memory signature"
        );
    }
    // And the replays reproduce the recording run's memory side.
    assert_eq!(strict.memory_signature(), recorded.memory_signature());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn strict_equals_event_under_cycle_budget_cut() {
    // Cut the budget mid-flight (including, almost surely, mid-stall for
    // the memory-bound app): the settlement on the max_cycles exit path
    // must charge exactly what strict per-cycle ticking charges — in the
    // serial *and* every sharded configuration — and all must report
    // cycles == max_cycles.
    let mut saw_cut = false;
    for budget in [1_000u64, 7_777, 20_011] {
        let app = apps::find("PVC").unwrap();
        let design = Design::caba(Algo::Bdi);
        let run_mode = |strict: bool, threads: usize| -> SimStats {
            let mut c = cfg(strict);
            c.max_cycles = budget;
            c.sim_threads = threads;
            Simulator::new(c, design, app, 0.05).run()
        };
        let strict = run_mode(true, 1);
        let mut runs = vec![("event-serial".to_string(), run_mode(false, 1))];
        for &threads in &THREADS {
            runs.push((format!("sharded x{threads}"), run_mode(false, threads)));
        }
        for (mode, got) in &runs {
            let label = format!("budget {budget} [{mode}]");
            assert_eq!(got.finished, strict.finished, "{label}: finished");
            assert_eq!(got.cycles, strict.cycles, "{label}: cycles");
            if !got.finished {
                // A budget-cut run must stop at exactly the budget in
                // every mode (the event paths clamp their fast-forwards).
                saw_cut = true;
                assert_eq!(got.cycles, budget, "{label}: clamp");
            }
            assert_eq!(got.warp_insts, strict.warp_insts, "{label}: warp_insts");
            assert_eq!(got.issue, strict.issue, "{label}: issue breakdown");
            assert_eq!(
                got.memory_signature(),
                strict.memory_signature(),
                "{label}: memory signature"
            );
        }
    }
    assert!(saw_cut, "no budget actually cut the run mid-flight — shrink the budgets");
}

/// Run one point with the flight recorder on and hand back both the
/// stats and the full recorded timeline.
fn run_with_telemetry(
    app_name: &str,
    design: Design,
    base: &SimConfig,
    strict: bool,
    threads: usize,
) -> (SimStats, caba::telemetry::TelemetryRun) {
    let app = apps::find(app_name).expect("differential app exists");
    let mut c = base.clone();
    c.strict_tick = strict;
    c.sim_threads = threads;
    let mut sim = Simulator::new(c, design, app, 0.02);
    let stats = sim.run();
    let run = sim.telemetry_run().expect("telemetry enabled in base config");
    (stats, run)
}

#[test]
fn telemetry_timelines_bit_identical_across_modes() {
    // window = 777: odd and coprime to every internal cadence, so window
    // boundaries constantly land inside event-mode fast-forwards — the
    // bulk-charge split's hardest case. One memory-bound compression
    // point (long skippable stalls, decompress/compress spans) and one
    // compute-bound memoization point (dense lookup/install spans).
    let pairs: &[(&str, Design)] =
        &[("PVC", Design::caba(Algo::Bdi)), ("FRAG", Design::caba_memo())];
    for &(app, design) in pairs {
        let mut base = cfg(false);
        base.telemetry_window = 777;
        let (strict_stats, strict_tl) = run_with_telemetry(app, design, &base, true, 1);
        let label = |mode: &str| format!("{app}/{} [{mode} vs strict]", design.name);
        // Non-vacuity: the run must produce a real timeline and spans.
        assert!(
            strict_tl.window_count() > 10,
            "{app}/{}: too few windows to be a meaningful differential",
            design.name
        );
        assert!(
            strict_tl.span_count() > 0,
            "{app}/{}: no assist-warp spans recorded",
            design.name
        );
        assert_eq!(strict_tl.cycles, strict_stats.cycles);

        let (serial_stats, serial_tl) = run_with_telemetry(app, design, &base, false, 1);
        assert_eq!(serial_stats.issue, strict_stats.issue, "{}", label("event-serial"));
        // Whole-struct equality: every window delta, every occupancy
        // sample, every span endpoint, the overcommit count.
        assert_eq!(serial_tl, strict_tl, "{}", label("event-serial"));
        for &threads in &THREADS {
            let (_, tl) = run_with_telemetry(app, design, &base, false, threads);
            assert_eq!(tl, strict_tl, "{}", label(&format!("sharded x{threads}")));
        }
    }
}

#[test]
fn telemetry_is_invisible_and_outside_the_fingerprint() {
    // Observation-only, end to end: the same point with the recorder off
    // and on (serial and sharded) produces bit-identical SimStats, and
    // the telemetry knobs don't move the config fingerprint.
    let base = cfg(false);
    let mut on = base.clone();
    on.telemetry_window = 777;
    on.telemetry_spans = 64;
    assert_eq!(
        on.fingerprint(),
        base.fingerprint(),
        "telemetry knobs must stay outside the config fingerprint"
    );
    let app = apps::find("PVC").unwrap();
    let design = Design::caba(Algo::Bdi);
    let off_stats = Simulator::new(base, design, app, 0.02).run();
    for threads in [1usize, 4] {
        let mut c = on.clone();
        c.sim_threads = threads;
        let mut sim = Simulator::new(c, design, app, 0.02);
        let stats = sim.run();
        assert_eq!(
            stats, off_stats,
            "recorder on changed SimStats at sim_threads={threads}"
        );
        assert!(sim.telemetry_run().is_some());
    }
}

/// Drive one hand-built core through the two-phase `cycle()`/`drain()`
/// protocol per-cycle vs. skip-and-settle, with identical surroundings,
/// and require the identical issue breakdown — the unit-level form of the
/// bulk-charge contract (and, since `drain` is exactly what the shard
/// loop's rendezvous runs, of the sharding contract too).
fn handbuilt_core_differential(app_name: &str, design: Design, horizon: u64) {
    let cfg = SimConfig::default();
    let app = apps::find(app_name).unwrap();
    let wl = Workload::build(app, &cfg, 0.01);
    let geom = MemoGeometry::for_workload(&cfg, &design, &wl);

    let run = |event: bool| {
        let mut core = Core::new(0, &cfg, &design, &geom);
        let mut mem = MemSystem::new(&cfg, &design);
        let mut data = DataModel::new(
            Box::new(caba::compress::oracle::MemoOracle::new(
                caba::compress::oracle::NativeOracle,
            )),
            &wl.arrays,
        );
        let mut stats = caba::stats::SimStats::default();
        core.launch_cta(0, 0, &wl);
        let mut t = 0u64;
        while t < horizon {
            if event && core.next_event > t {
                // Jump straight to the wake (clamped to the horizon); the
                // skipped window settles inside the next cycle() call or
                // the final settle_to below.
                t = core.next_event.min(horizon);
                continue;
            }
            // Phase A: core-local work against read-only shared state.
            let cctx = CoreCtx { cfg: &cfg, design: &design, wl: &wl };
            core.cycle(t, &cctx);
            // Phase B: drain the queued shared-memory ops immediately —
            // exactly what the serial run loop (and, per shard epoch, the
            // rendezvous) does.
            let mut dctx = DrainCtx {
                cfg: &cfg,
                design: &design,
                wl: &wl,
                mem: &mut mem,
                data: &mut data,
                stats: &mut stats,
            };
            core.drain(t, &mut dctx);
            t += 1;
        }
        core.settle_to(horizon, &cfg, &design);
        core.issue
    };

    let per_cycle = run(false);
    let skipped = run(true);
    assert_eq!(
        skipped, per_cycle,
        "{app_name}/{}: bulk-charged breakdown diverged from per-cycle",
        design.name
    );
    assert_eq!(
        per_cycle.total(),
        horizon * cfg.schedulers_per_sm as u64,
        "{app_name}/{}: hand-built core lost scheduler slots",
        design.name
    );
    // The scenario must actually exercise stalls, or the test is vacuous.
    assert!(
        per_cycle.total() > per_cycle.active,
        "{app_name}/{}: no stall cycles in the hand-built scenario",
        design.name
    );
}

#[test]
fn bulk_charged_stalls_match_per_cycle_on_handbuilt_core() {
    // Memory-structural + data-dependence windows (long DRAM stalls).
    handbuilt_core_differential("PVC", Design::caba(Algo::Bdi), 20_000);
    // Compute-structural windows (busy quarter-rate SFU pipes) and the
    // memo lookup/install machinery.
    handbuilt_core_differential("FRAG", Design::caba_memo(), 20_000);
    // Plain baseline.
    handbuilt_core_differential("SLA", Design::base(), 20_000);
}
