//! Property tests over the compression substrates and coordinator
//! invariants (mini-prop harness; `proptest` is unavailable offline —
//! see DESIGN.md §3). Replay a failure with CABA_PROP_SEED=<seed>.

use caba::compress::oracle::{CompressionOracle, MemoOracle, NativeOracle};
use caba::compress::{bursts_for, compress, decompress, Algo, Line, LINE_BYTES};
use caba::prop_assert;
use caba::util::miniprop::{default_cases, forall};
use caba::util::rng::Rng;
use caba::workload::datagen::{line_data, DataPattern};

fn arb_line(rng: &mut Rng) -> Line {
    // Mix raw-random lines with structured ones so every encoding path is
    // exercised, not just the uncompressed fallback.
    let patterns = [
        DataPattern::ZeroHeavy { p_zero: 0.5 },
        DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 },
        DataPattern::LowDynRange { value_bytes: 2, delta_bytes: 1 },
        DataPattern::NarrowInt { max: 200 },
        DataPattern::PointerLike { n_bases: 3 },
        DataPattern::RepBytes,
        DataPattern::SparseNarrow { p_nonzero: 0.4 },
        DataPattern::Random,
    ];
    if rng.chance(0.3) {
        let mut line = [0u8; LINE_BYTES];
        for b in line.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        line
    } else {
        let p = patterns[rng.range(0, patterns.len())];
        line_data(&p, rng.next_u64(), rng.next_u64() % 10_000, 0)
    }
}

#[test]
fn prop_roundtrip_all_algorithms() {
    forall("roundtrip", default_cases() * 4, arb_line, |line| {
        for algo in Algo::CONCRETE {
            let c = compress(algo, line);
            let back = decompress(&c);
            prop_assert!(
                &back == line,
                "{algo:?} enc={} failed roundtrip",
                c.encoding
            );
        }
        Ok(())
    });
}

#[test]
fn prop_compressed_size_bounded() {
    forall("size-bound", default_cases() * 2, arb_line, |line| {
        for algo in Algo::CONCRETE {
            let c = compress(algo, line);
            prop_assert!(
                c.size_bytes() <= LINE_BYTES + 1,
                "{algo:?}: size {} exceeds passthrough",
                c.size_bytes()
            );
            prop_assert!(
                (1..=4).contains(&c.bursts()),
                "{algo:?}: bursts {}",
                c.bursts()
            );
            prop_assert!(
                c.bursts() == bursts_for(c.size_bytes()),
                "{algo:?}: burst accounting"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_best_of_all_is_minimum() {
    forall("best-min", default_cases(), arb_line, |line| {
        let best = compress(Algo::BestOfAll, line);
        for algo in Algo::CONCRETE {
            let c = compress(algo, line);
            prop_assert!(
                best.size_bytes() <= c.size_bytes(),
                "best {} > {algo:?} {}",
                best.size_bytes(),
                c.size_bytes()
            );
        }
        // And BestOfAll lines must still decompress via their carried algo.
        let back = decompress(&best);
        prop_assert!(&back == line, "best roundtrip");
        Ok(())
    });
}

#[test]
fn prop_memo_oracle_transparent() {
    let mut memo = MemoOracle::new(NativeOracle);
    let mut native = NativeOracle;
    forall("memo", default_cases(), arb_line, move |line| {
        for algo in Algo::CONCRETE {
            let a = memo.analyze_one(algo, line);
            let b = native.analyze_one(algo, line);
            prop_assert!(a == b, "{algo:?}: memo {a:?} != native {b:?}");
            // Second query must hit the memo and agree.
            let c = memo.analyze_one(algo, line);
            prop_assert!(a == c, "{algo:?}: memo unstable");
        }
        Ok(())
    });
}

#[test]
fn prop_verdict_matches_compressor() {
    let mut oracle = NativeOracle;
    forall("verdict", default_cases(), arb_line, move |line| {
        for algo in Algo::CONCRETE {
            let v = oracle.analyze_one(algo, line);
            let c = compress(algo, line);
            prop_assert!(v.size_bytes as usize == c.size_bytes(), "{algo:?} size");
            prop_assert!(v.encoding == c.encoding, "{algo:?} encoding");
            prop_assert!(v.bursts == c.bursts(), "{algo:?} bursts");
        }
        Ok(())
    });
}

#[test]
fn prop_datagen_deterministic_and_epoch_sensitive() {
    forall(
        "datagen",
        default_cases(),
        |rng: &mut Rng| (rng.next_u64(), rng.next_u64() % 1000),
        |&(seed, addr)| {
            let p = DataPattern::LowDynRange { value_bytes: 4, delta_bytes: 1 };
            let a = line_data(&p, seed, addr, 0);
            let b = line_data(&p, seed, addr, 0);
            prop_assert!(a == b, "not deterministic");
            let c = line_data(&p, seed, addr, 1);
            prop_assert!(a != c, "epoch ignored");
            Ok(())
        },
    );
}

#[test]
fn prop_cache_insert_then_probe_hits() {
    use caba::mem::cache::Cache;
    forall(
        "cache-hit",
        default_cases(),
        |rng: &mut Rng| {
            let addrs: Vec<u64> = (0..16).map(|_| rng.next_u64() % 4096).collect();
            addrs
        },
        |addrs| {
            let mut c = Cache::new(16 * 1024, 4, 128, 1);
            for (t, &a) in addrs.iter().enumerate() {
                c.insert(a, false, 4, false, t as u64);
                prop_assert!(c.contains(a), "inserted line missing");
            }
            // The most recent insert always survives.
            prop_assert!(c.contains(*addrs.last().unwrap()), "MRU evicted");
            Ok(())
        },
    );
}

#[test]
fn prop_simulation_deterministic() {
    use caba::sim::designs::Design;
    use caba::sim::Simulator;
    // Two runs with identical seeds must agree exactly — across apps and
    // designs (routing/batching/state management determinism).
    let apps = ["PVC", "BFS", "MM"];
    forall(
        "sim-determinism",
        3,
        {
            let mut i = 0;
            move |_rng: &mut Rng| {
                let name = apps[i % apps.len()];
                i += 1;
                name
            }
        },
        |name| {
            let app = caba::workload::apps::find(name).unwrap();
            let mut cfg = caba::SimConfig::default();
            cfg.n_sms = 2;
            cfg.max_cycles = 300_000;
            let d = Design::caba(Algo::Bdi);
            let a = Simulator::new(cfg.clone(), d, app, 0.005).run();
            let b = Simulator::new(cfg, d, app, 0.005).run();
            prop_assert!(a.cycles == b.cycles, "cycles differ");
            prop_assert!(a.warp_insts == b.warp_insts, "insts differ");
            prop_assert!(a.dram.bursts == b.dram.bursts, "bursts differ");
            prop_assert!(
                a.caba.decompress_warps == b.caba.decompress_warps,
                "assist warps differ"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_icnt_port_times_monotone() {
    use caba::mem::icnt::Port;
    forall(
        "icnt-monotone",
        default_cases(),
        |rng: &mut Rng| {
            (0..16)
                .map(|_| (rng.below(1000) as f64, 32.0 + rng.below(128) as f64))
                .collect::<Vec<(f64, f64)>>()
        },
        |xfers| {
            let mut p = Port::new(32.0);
            let mut last_done = 0.0f64;
            let mut sorted = xfers.clone();
            sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for &(now, bytes) in &sorted {
                let done = p.transfer(now, bytes);
                prop_assert!(done >= now, "completion before start");
                prop_assert!(done >= last_done, "port reordered transfers");
                prop_assert!(
                    done - now.max(last_done) >= bytes / 32.0 - 1e-9,
                    "transfer faster than port bandwidth"
                );
                last_done = done;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_energy_monotone_in_events() {
    use caba::energy::EnergyModel;
    use caba::stats::SimStats;
    forall(
        "energy-monotone",
        default_cases(),
        |rng: &mut Rng| (rng.below(1_000_000), rng.below(1_000_000), rng.below(100_000) + 1),
        |&(bursts, insts, cycles)| {
            let em = EnergyModel::default();
            let mut a = SimStats::default();
            a.cycles = cycles;
            a.energy_events.dram_bursts = bursts;
            a.energy_events.core_insts = insts;
            let mut b = a.clone();
            b.energy_events.dram_bursts += 1000;
            let ea = em.evaluate(&a, false, false).total_mj();
            let eb = em.evaluate(&b, false, false).total_mj();
            prop_assert!(eb > ea, "more DRAM bursts must cost more energy");
            Ok(())
        },
    );
}

#[test]
fn prop_bursts_for_monotone_and_bounded() {
    forall(
        "bursts-monotone",
        default_cases(),
        |rng: &mut Rng| rng.below(256) as usize,
        |&size| {
            let b = bursts_for(size);
            let b2 = bursts_for(size + 1);
            prop_assert!(b2 >= b, "bursts not monotone in size");
            prop_assert!((1..=4).contains(&b), "bursts out of range");
            Ok(())
        },
    );
}
