//! Property tests over the compression substrates and coordinator
//! invariants (mini-prop harness; `proptest` is unavailable offline —
//! see DESIGN.md §3). Replay a failure with CABA_PROP_SEED=<seed>.

use caba::compress::oracle::{CompressionOracle, MemoOracle, NativeOracle};
use caba::compress::{bursts_for, compress, decompress, Algo, Line, LINE_BYTES};
use caba::prop_assert;
use caba::util::miniprop::{default_cases, forall};
use caba::util::rng::Rng;
use caba::workload::datagen::{line_data, DataPattern};

fn arb_line(rng: &mut Rng) -> Line {
    // Mix raw-random lines with structured ones so every encoding path is
    // exercised, not just the uncompressed fallback.
    let patterns = [
        DataPattern::ZeroHeavy { p_zero: 0.5 },
        DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 },
        DataPattern::LowDynRange { value_bytes: 2, delta_bytes: 1 },
        DataPattern::NarrowInt { max: 200 },
        DataPattern::PointerLike { n_bases: 3 },
        DataPattern::RepBytes,
        DataPattern::SparseNarrow { p_nonzero: 0.4 },
        DataPattern::Random,
    ];
    if rng.chance(0.3) {
        let mut line = [0u8; LINE_BYTES];
        for b in line.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        line
    } else {
        let p = patterns[rng.range(0, patterns.len())];
        line_data(&p, rng.next_u64(), rng.next_u64() % 10_000, 0)
    }
}

#[test]
fn prop_roundtrip_all_algorithms() {
    forall("roundtrip", default_cases() * 4, arb_line, |line| {
        for algo in Algo::CONCRETE {
            let c = compress(algo, line);
            let back = decompress(&c);
            prop_assert!(
                &back == line,
                "{algo:?} enc={} failed roundtrip",
                c.encoding
            );
        }
        Ok(())
    });
}

#[test]
fn prop_compressed_size_bounded() {
    forall("size-bound", default_cases() * 2, arb_line, |line| {
        for algo in Algo::CONCRETE {
            let c = compress(algo, line);
            prop_assert!(
                c.size_bytes() <= LINE_BYTES + 1,
                "{algo:?}: size {} exceeds passthrough",
                c.size_bytes()
            );
            prop_assert!(
                (1..=4).contains(&c.bursts()),
                "{algo:?}: bursts {}",
                c.bursts()
            );
            prop_assert!(
                c.bursts() == bursts_for(c.size_bytes()),
                "{algo:?}: burst accounting"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_best_of_all_is_minimum() {
    forall("best-min", default_cases(), arb_line, |line| {
        let best = compress(Algo::BestOfAll, line);
        for algo in Algo::CONCRETE {
            let c = compress(algo, line);
            prop_assert!(
                best.size_bytes() <= c.size_bytes(),
                "best {} > {algo:?} {}",
                best.size_bytes(),
                c.size_bytes()
            );
        }
        // And BestOfAll lines must still decompress via their carried algo.
        let back = decompress(&best);
        prop_assert!(&back == line, "best roundtrip");
        Ok(())
    });
}

#[test]
fn prop_memo_oracle_transparent() {
    let mut memo = MemoOracle::new(NativeOracle);
    let mut native = NativeOracle;
    forall("memo", default_cases(), arb_line, move |line| {
        for algo in Algo::CONCRETE {
            let a = memo.analyze_one(algo, line);
            let b = native.analyze_one(algo, line);
            prop_assert!(a == b, "{algo:?}: memo {a:?} != native {b:?}");
            // Second query must hit the memo and agree.
            let c = memo.analyze_one(algo, line);
            prop_assert!(a == c, "{algo:?}: memo unstable");
        }
        Ok(())
    });
}

#[test]
fn prop_verdict_matches_compressor() {
    let mut oracle = NativeOracle;
    forall("verdict", default_cases(), arb_line, move |line| {
        for algo in Algo::CONCRETE {
            let v = oracle.analyze_one(algo, line);
            let c = compress(algo, line);
            prop_assert!(v.size_bytes as usize == c.size_bytes(), "{algo:?} size");
            prop_assert!(v.encoding == c.encoding, "{algo:?} encoding");
            prop_assert!(v.bursts == c.bursts(), "{algo:?} bursts");
        }
        Ok(())
    });
}

#[test]
fn prop_datagen_deterministic_and_epoch_sensitive() {
    forall(
        "datagen",
        default_cases(),
        |rng: &mut Rng| (rng.next_u64(), rng.next_u64() % 1000),
        |&(seed, addr)| {
            let p = DataPattern::LowDynRange { value_bytes: 4, delta_bytes: 1 };
            let a = line_data(&p, seed, addr, 0);
            let b = line_data(&p, seed, addr, 0);
            prop_assert!(a == b, "not deterministic");
            let c = line_data(&p, seed, addr, 1);
            prop_assert!(a != c, "epoch ignored");
            Ok(())
        },
    );
}

#[test]
fn prop_cache_insert_then_probe_hits() {
    use caba::mem::cache::Cache;
    forall(
        "cache-hit",
        default_cases(),
        |rng: &mut Rng| {
            let addrs: Vec<u64> = (0..16).map(|_| rng.next_u64() % 4096).collect();
            addrs
        },
        |addrs| {
            let mut c = Cache::new(16 * 1024, 4, 128, 1);
            for (t, &a) in addrs.iter().enumerate() {
                c.insert(a, false, 4, false, t as u64);
                prop_assert!(c.contains(a), "inserted line missing");
            }
            // The most recent insert always survives.
            prop_assert!(c.contains(*addrs.last().unwrap()), "MRU evicted");
            Ok(())
        },
    );
}

#[test]
fn prop_simulation_deterministic() {
    use caba::sim::designs::Design;
    use caba::sim::Simulator;
    // Two runs with identical seeds must agree exactly — across apps and
    // designs (routing/batching/state management determinism).
    let apps = ["PVC", "BFS", "MM"];
    forall(
        "sim-determinism",
        3,
        {
            let mut i = 0;
            move |_rng: &mut Rng| {
                let name = apps[i % apps.len()];
                i += 1;
                name
            }
        },
        |name| {
            let app = caba::workload::apps::find(name).unwrap();
            let mut cfg = caba::SimConfig::default();
            cfg.n_sms = 2;
            cfg.max_cycles = 300_000;
            let d = Design::caba(Algo::Bdi);
            let a = Simulator::new(cfg.clone(), d, app, 0.005).run();
            let b = Simulator::new(cfg, d, app, 0.005).run();
            prop_assert!(a.cycles == b.cycles, "cycles differ");
            prop_assert!(a.warp_insts == b.warp_insts, "insts differ");
            prop_assert!(a.dram.bursts == b.dram.bursts, "bursts differ");
            prop_assert!(
                a.caba.decompress_warps == b.caba.decompress_warps,
                "assist warps differ"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_icnt_port_times_monotone() {
    use caba::mem::icnt::Port;
    forall(
        "icnt-monotone",
        default_cases(),
        |rng: &mut Rng| {
            (0..16)
                .map(|_| (rng.below(1000) as f64, 32.0 + rng.below(128) as f64))
                .collect::<Vec<(f64, f64)>>()
        },
        |xfers| {
            let mut p = Port::new(32.0);
            let mut last_done = 0.0f64;
            let mut sorted = xfers.clone();
            sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for &(now, bytes) in &sorted {
                let done = p.transfer(now, bytes);
                prop_assert!(done >= now, "completion before start");
                prop_assert!(done >= last_done, "port reordered transfers");
                prop_assert!(
                    done - now.max(last_done) >= bytes / 32.0 - 1e-9,
                    "transfer faster than port bandwidth"
                );
                last_done = done;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_energy_monotone_in_events() {
    use caba::energy::EnergyModel;
    use caba::stats::SimStats;
    forall(
        "energy-monotone",
        default_cases(),
        |rng: &mut Rng| (rng.below(1_000_000), rng.below(1_000_000), rng.below(100_000) + 1),
        |&(bursts, insts, cycles)| {
            let em = EnergyModel::default();
            let mut a = SimStats::default();
            a.cycles = cycles;
            a.energy_events.dram_bursts = bursts;
            a.energy_events.core_insts = insts;
            let mut b = a.clone();
            b.energy_events.dram_bursts += 1000;
            let ea = em.evaluate(&a, false, false).total_mj();
            let eb = em.evaluate(&b, false, false).total_mj();
            prop_assert!(eb > ea, "more DRAM bursts must cost more energy");
            Ok(())
        },
    );
}

#[test]
fn prop_trace_varint_zigzag_roundtrip() {
    use caba::trace::codec::{put_varint, put_zigzag, Reader};
    forall(
        "trace-varint",
        default_cases() * 4,
        |rng: &mut Rng| {
            // Bias toward interesting magnitudes: small, medium, full-width.
            let shift = rng.below(64) as u32;
            (rng.next_u64() >> shift, rng.next_u64() as i64 >> shift)
        },
        |&(u, s)| {
            let mut buf = Vec::new();
            put_varint(&mut buf, u);
            put_zigzag(&mut buf, s);
            let mut r = Reader::new(&buf);
            prop_assert!(r.varint().unwrap() == u, "varint roundtrip {u}");
            prop_assert!(r.zigzag().unwrap() == s, "zigzag roundtrip {s}");
            prop_assert!(r.remaining() == 0, "stray bytes");
            Ok(())
        },
    );
}

#[test]
fn prop_trace_rle_line_roundtrip() {
    use caba::trace::codec::{rle_decode_line, rle_encode_line, Reader};
    forall("trace-rle", default_cases() * 2, arb_line, |line| {
        let mut buf = Vec::new();
        rle_encode_line(line, &mut buf);
        prop_assert!(buf.len() <= 1 + LINE_BYTES, "RLE expanded past raw fallback");
        let mut r = Reader::new(&buf);
        let back = rle_decode_line(&mut r).map_err(|e| format!("{e:#}"))?;
        prop_assert!(&back == line, "RLE roundtrip mismatch");
        prop_assert!(r.remaining() == 0, "stray bytes after line");
        Ok(())
    });
}

/// Generated trace content for the stream-level round-trip: deduplicated
/// access records over coalesced / strided / scatter address shapes, plus
/// payload entries.
type TraceContent = (Vec<(u64, u32, u32, bool, Vec<u64>)>, Vec<(u64, u32, Line)>);

fn arb_trace_content(rng: &mut Rng) -> TraceContent {
    use std::collections::HashSet;
    let base = 1u64 << 40; // workload array base
    let mut accesses = Vec::new();
    let mut keys = HashSet::new();
    for _ in 0..1 + rng.below(40) {
        let key = (rng.below(1 << 20), rng.below(1 << 10) as u32, rng.below(8) as u32);
        if !keys.insert(key) {
            continue;
        }
        let lines: Vec<u64> = match rng.below(3) {
            // Coalesced: one line.
            0 => vec![base + rng.below(1 << 16)],
            // Strided: consecutive lines.
            1 => {
                let s = base + rng.below(1 << 16);
                (0..2 + rng.below(7)).map(|j| s + j).collect()
            }
            // Scatter: arbitrary lines (duplicates allowed, order matters).
            _ => (0..1 + rng.below(6)).map(|_| base + rng.below(1 << 16)).collect(),
        };
        accesses.push((key.0, key.1, key.2, rng.chance(0.3), lines));
    }
    let mut payloads = Vec::new();
    let mut pkeys = HashSet::new();
    for _ in 0..rng.below(16) {
        let key = (base + rng.below(1 << 12), rng.below(4) as u32);
        if pkeys.insert(key) {
            payloads.push((key.0, key.1, arb_line(rng)));
        }
    }
    (accesses, payloads)
}

#[test]
fn prop_trace_stream_roundtrip_and_truncation() {
    use caba::trace::record::encode_in_memory;
    use caba::trace::replay::TraceData;
    use caba::trace::{TraceKind, TraceMeta, PATTERN_FROM_SPEC};
    let meta = TraceMeta {
        kind: TraceKind::Recorded,
        fingerprint: 0xF00D,
        seed: 7,
        scale: 0.25,
        app: "PVC".into(),
        regs_per_thread: 16,
        threads_per_cta: 256,
        smem_per_cta: 0,
        total_ctas: 4,
        iters: 1024,
        arrays: vec![(1 << 16, PATTERN_FROM_SPEC)],
    };
    forall("trace-stream", default_cases() / 4, arb_trace_content, move |content| {
        let (accesses, payloads) = content;
        let bytes = encode_in_memory(&meta, accesses, payloads).map_err(|e| format!("{e:#}"))?;
        let t = TraceData::from_bytes(&bytes).map_err(|e| format!("{e:#}"))?;
        // encode → decode == identity, including line order within records.
        let mut out = Vec::new();
        for &(uid, iter, slot, _, ref lines) in accesses {
            t.access_into(uid, iter, slot as usize, &mut out);
            prop_assert!(&out == lines, "access ({uid},{iter},{slot}) mismatch");
        }
        for &(line, epoch, ref data) in payloads {
            let got = t.payload(line, epoch);
            prop_assert!(got.as_ref() == Some(data), "payload ({line},{epoch}) mismatch");
        }
        prop_assert!(
            t.n_access_records == accesses.len() as u64,
            "record count {} != {}",
            t.n_access_records,
            accesses.len()
        );
        // Every strict prefix must fail loudly, never mis-parse.
        for cut in [bytes.len() / 4, bytes.len() / 2, bytes.len() - 1] {
            prop_assert!(
                TraceData::from_bytes(&bytes[..cut]).is_err(),
                "truncation at {cut}/{} parsed",
                bytes.len()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_bursts_for_monotone_and_bounded() {
    forall(
        "bursts-monotone",
        default_cases(),
        |rng: &mut Rng| rng.below(256) as usize,
        |&size| {
            let b = bursts_for(size);
            let b2 = bursts_for(size + 1);
            prop_assert!(b2 >= b, "bursts not monotone in size");
            prop_assert!((1..=4).contains(&b), "bursts out of range");
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- §8.1 memo

/// Drive `n` deterministically generated SFU invocations through a fresh
/// LUT (install-on-miss, like the core does) and return (hits, lookups).
fn memo_stream_hits(
    lut: &mut caba::memo::MemoLut,
    vs: &caba::workload::values::ValueSpec,
    seed: u64,
    n: u64,
) -> (u64, u64) {
    use caba::memo::Lookup;
    use caba::workload::values::operand_key;
    let mut hits = 0;
    for i in 0..n {
        // 32 warps round-robin through iterations of one SFU slot.
        let key = operand_key(vs, seed, i % 32, (i / 32) as u32, 3);
        match lut.lookup(key, i) {
            Lookup::Hit | Lookup::AliasHit => hits += 1,
            Lookup::Miss => {
                lut.install(key, i);
            }
            Lookup::Disabled => {}
        }
    }
    (hits, n)
}

#[test]
fn prop_memo_lut_occupancy_never_exceeds_budget() {
    use caba::memo::{Lookup, MemoGeometry, MemoLut};
    forall(
        "memo-lut-occupancy",
        64,
        |rng: &mut Rng| {
            (
                rng.below(64) + 1,  // sets
                rng.below(8) + 1,   // ways
                rng.below(48) + 8,  // entry bytes
                rng.next_u64(),     // key-stream seed
            )
        },
        |&(sets, ways, entry_bytes, seed)| {
            let geom =
                MemoGeometry::explicit(sets as usize, ways as usize, entry_bytes as usize, 16);
            let mut lut = MemoLut::new(geom);
            let mut rng = Rng::new(seed);
            for now in 0..2048u64 {
                let key = rng.below(sets * ways * 4); // enough to overflow
                if lut.lookup(key, now) == Lookup::Miss {
                    lut.install(key, now);
                }
                prop_assert!(
                    lut.occupancy() <= lut.capacity(),
                    "occupancy {} > capacity {}",
                    lut.occupancy(),
                    lut.capacity()
                );
                prop_assert!(
                    lut.occupancy() * geom.entry_bytes <= geom.budget_bytes,
                    "occupancy exceeds the shared-memory budget"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_memo_hit_rate_monotone_in_value_redundancy() {
    use caba::memo::{MemoGeometry, MemoLut};
    use caba::workload::values::ValueSpec;
    forall(
        "memo-hit-monotone",
        24,
        |rng: &mut Rng| {
            (
                rng.below(4000) as f64 / 10_000.0,       // p_lo in [0, 0.4)
                0.2 + rng.below(3500) as f64 / 10_000.0, // delta in [0.2, 0.55)
                64u32 << rng.below(7),                   // classes: 64..4096
                rng.next_u64(),
            )
        },
        |&(p_lo, delta, classes, seed)| {
            let rate = |p: f64| {
                let mut lut = MemoLut::new(MemoGeometry::explicit(64, 4, 16, 16));
                let vs = ValueSpec::shared(p, classes);
                let (hits, n) = memo_stream_hits(&mut lut, &vs, seed, 6000);
                hits as f64 / n as f64
            };
            let lo = rate(p_lo);
            let hi = rate(p_lo + delta);
            // Same seed ⇒ the shared-draw set under p_lo is a subset of the
            // one under p_hi; tolerance absorbs eviction-order noise.
            prop_assert!(
                hi + 0.02 >= lo,
                "hit rate not monotone: p={p_lo:.3}→{lo:.3}, p={:.3}→{hi:.3}",
                p_lo + delta
            );
            Ok(())
        },
    );
}

// ------------------------------------------------------------- run store

/// The store codec writes `SimStats` as fixed-width little-endian words
/// plus one trailing bool byte, so a uniformly random well-formed payload
/// reaches every field with an arbitrary bit pattern — including the one
/// `f64`, which must round-trip through `to_bits`/`from_bits` untouched.
/// Byte-level re-encode identity is the pinned property (struct-level
/// `PartialEq` would reject NaN even though the codec preserves it).
#[test]
fn prop_store_codec_roundtrip() {
    use caba::stats::SimStats;
    use caba::store::{decode_stats, encode_stats, stats_digest};
    let payload_len = {
        let mut buf = Vec::new();
        encode_stats(&SimStats::default(), &mut buf);
        buf.len()
    };
    let words = (payload_len - 1) / 8;
    forall(
        "store-codec",
        default_cases(),
        |rng: &mut Rng| {
            let mut buf = Vec::with_capacity(payload_len);
            for _ in 0..words {
                buf.extend_from_slice(&rng.next_u64().to_le_bytes());
            }
            buf.push((rng.next_u32() & 1) as u8);
            buf
        },
        |payload| {
            let s = decode_stats(payload).map_err(|e| format!("{e:#}"))?;
            let mut back = Vec::new();
            encode_stats(&s, &mut back);
            prop_assert!(&back == payload, "re-encode diverged from source bytes");
            // With a finite float, struct-level equality and the serve
            // digest must agree with the byte-level identity.
            if s.dram.bus_busy_cycles.is_finite() {
                let s2 = decode_stats(&back).map_err(|e| format!("{e:#}"))?;
                prop_assert!(s2 == s, "struct roundtrip mismatch");
                prop_assert!(stats_digest(&s2) == stats_digest(&s), "digest unstable");
            }
            // Truncation never mis-parses, at any depth.
            let cut = payload.len() / 2;
            prop_assert!(decode_stats(&payload[..cut]).is_err(), "truncated prefix parsed");
            prop_assert!(
                decode_stats(&payload[..payload.len() - 1]).is_err(),
                "payload missing its bool byte parsed"
            );
            Ok(())
        },
    );
}

// ---- obs histogram properties (PR 9) ------------------------------------

/// A value mix spanning all bucket regimes: zeros, small ints, exact
/// powers of two and their neighbours, and full-range randoms.
fn arb_latencies(rng: &mut Rng) -> Vec<u64> {
    let n = 1 + rng.range(0, 64);
    (0..n)
        .map(|_| match rng.range(0, 5) {
            0 => 0,
            1 => rng.next_u64() % 16,
            2 => 1u64 << rng.range(0, 63),
            3 => (1u64 << rng.range(0, 63)).wrapping_sub(1),
            _ => rng.next_u64(),
        })
        .collect()
}

#[test]
fn prop_hist_percentile_brackets_sorted_model() {
    use caba::obs::Histogram;
    forall("hist-percentile", default_cases(), arb_latencies, |values| {
        let h = Histogram::new();
        for &v in values {
            h.record(v);
        }
        let snap = h.snapshot();
        let mut sorted = values.clone();
        sorted.sort_unstable();
        for &q in &[0.01, 0.50, 0.95, 0.99, 1.0] {
            // The model: the rank-th smallest value, the same rank rule
            // the bucketed estimate uses.
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let t = sorted[rank - 1];
            let p = snap.percentile(q);
            // Log2 buckets bracket the truth: never below it, and within
            // one bucket (a factor of 2) above. u128 avoids overflow at
            // the top bucket.
            prop_assert!(p >= t, "p{q}: estimate {p} below true {t}");
            prop_assert!(
                (p as u128) < 2 * (t.max(1) as u128),
                "p{q}: estimate {p} not within 2x of true {t}"
            );
        }
        prop_assert!(snap.count == values.len() as u64, "count mismatch");
        Ok(())
    });
}

#[test]
fn prop_hist_merge_is_associative_and_commutative() {
    use caba::obs::{HistSnapshot, Histogram};
    forall(
        "hist-merge",
        default_cases(),
        |rng| (arb_latencies(rng), arb_latencies(rng), arb_latencies(rng)),
        |(xs, ys, zs)| {
            let snap = |vals: &Vec<u64>| {
                let h = Histogram::new();
                for &v in vals {
                    h.record(v);
                }
                h.snapshot()
            };
            let (a, b, c) = (snap(xs), snap(ys), snap(zs));
            prop_assert!(a.merge(&b) == b.merge(&a), "merge not commutative");
            prop_assert!(
                a.merge(&b).merge(&c) == a.merge(&b.merge(&c)),
                "merge not associative"
            );
            prop_assert!(a.merge(&HistSnapshot::empty()) == a, "empty is not identity");
            // A merged snapshot answers percentiles exactly as one
            // histogram fed both streams would.
            let both = Histogram::new();
            for &v in xs.iter().chain(ys) {
                both.record(v);
            }
            prop_assert!(a.merge(&b) == both.snapshot(), "merge != combined stream");
            Ok(())
        },
    );
}

#[test]
fn prop_hist_bucket_boundaries_are_powers_of_two() {
    use caba::obs::hist::{bucket_index, bucket_upper_bound};
    forall(
        "hist-bucket",
        default_cases(),
        |rng| rng.next_u64(),
        |&v| {
            let i = bucket_index(v);
            prop_assert!(v <= bucket_upper_bound(i), "{v} above its bucket bound");
            if i > 0 {
                prop_assert!(v > bucket_upper_bound(i - 1), "{v} overlaps bucket {}", i - 1);
            } else {
                prop_assert!(v == 0, "only 0 lands in bucket 0, got {v}");
            }
            Ok(())
        },
    );
}

// ---- serve wire JSON properties (PR 10) ---------------------------------

/// Arbitrary wire-JSON values: every scalar regime (finite doubles from
/// raw bit patterns, exact small ints, nasty strings full of quotes,
/// escapes, control bytes and multi-byte UTF-8) plus bounded-depth
/// arrays and objects with duplicate-prone short keys.
fn arb_json(rng: &mut Rng, depth: usize) -> caba::serve::json::Json {
    use caba::serve::json::Json;
    let arb_string = |rng: &mut Rng| -> String {
        let n = rng.range(0, 12);
        (0..n)
            .map(|_| match rng.range(0, 6) {
                0 => '"',
                1 => '\\',
                2 => char::from(rng.next_u32() as u8 % 0x20), // control
                3 => 'é',
                4 => '𝄞', // needs a surrogate pair on the wire
                _ => char::from(b'a' + (rng.next_u32() as u8 % 26)),
            })
            .collect()
    };
    let n_kinds = if depth == 0 { 4 } else { 6 };
    match rng.range(0, n_kinds) {
        0 => Json::Null,
        1 => Json::Bool(rng.chance(0.5)),
        2 => {
            if rng.chance(0.5) {
                Json::Num((rng.next_u64() % 2_000) as f64 - 1_000.0)
            } else {
                // Raw bit patterns, rerolled until finite: exercises
                // subnormals, huge magnitudes and negative zero.
                loop {
                    let f = f64::from_bits(rng.next_u64());
                    if f.is_finite() {
                        break Json::Num(f);
                    }
                }
            }
        }
        3 => Json::Str(arb_string(rng)),
        4 => Json::Arr((0..rng.range(0, 4)).map(|_| arb_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.range(0, 4))
                .map(|_| (arb_string(rng), arb_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// `Display` is a right inverse of `parse`: any value the generator can
/// build survives a serialize→parse round trip, and the serialized form
/// is a fixpoint (printing the reparsed value is byte-identical).
#[test]
fn prop_json_display_parse_roundtrip() {
    use caba::serve::json::parse;
    forall(
        "json-roundtrip",
        default_cases() * 2,
        |rng: &mut Rng| arb_json(rng, 4),
        |v| {
            let wire = v.to_string();
            let back = parse(&wire).map_err(|e| format!("{wire:?} did not reparse: {e:#}"))?;
            prop_assert!(&back == v, "round trip changed the value: {wire}");
            prop_assert!(back.to_string() == wire, "serialized form is not a fixpoint");
            Ok(())
        },
    );
}

/// The malformed corpus: every entry must be *rejected* — errors, never
/// panics, stack overflows or silent truncation. Families: truncated
/// escape sequences, nesting past the depth limit, and numbers too large
/// for a finite f64.
#[test]
fn json_malformed_corpus_is_rejected() {
    use caba::serve::json::parse;
    let mut corpus: Vec<String> = vec![
        // Truncated escapes, in every spot an escape can be cut short.
        r#""\"#.into(),
        r#""abc\"#.into(),
        r#""\u"#.into(),
        r#""\u00"#.into(),
        r#""\u123"#.into(),
        r#""\ud834\u"#.into(),
        r#""\ud834\udd"#.into(),
        r#"{"k":"\"#.into(),
        r#""\x41""#.into(), // bad escape letter
        // Huge numbers: syntactically fine, semantically non-finite.
        "1e999".into(),
        "-1e999".into(),
        "1e309".into(),
        "9".repeat(400),
        r#"{"n":1e999}"#.into(),
    ];
    // Deep nesting: one past the limit must fail, for arrays and objects.
    corpus.push("[".repeat(33) + &"]".repeat(33));
    corpus.push("{\"k\":".repeat(33) + "0" + &"}".repeat(33));
    for bad in &corpus {
        assert!(parse(bad).is_err(), "{bad:?} must be rejected");
    }
    // The boundary itself is accepted: exactly MAX_DEPTH nested arrays.
    let at_limit = "[".repeat(32) + &"]".repeat(32);
    assert!(parse(&at_limit).is_ok(), "depth-32 value must still parse");
}
