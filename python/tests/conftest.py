import jax

# BDI needs uint64 arithmetic; must be set before any tracing.
jax.config.update("jax_enable_x64", True)
