"""Pallas kernels vs pure-jnp reference oracles — the core L1 correctness
signal, swept over structured and random line batches with hypothesis."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import BLOCK, KERNEL_FNS
from compile.kernels.ref import REF_FNS

ALGOS = ["bdi", "fpc", "cpack"]


def lines(n, gen):
    """Build a uint32[n, 32] batch from a per-line generator."""
    return np.stack([gen(i) for i in range(n)]).astype(np.uint32)


def pattern_batch(seed: int, n: int = BLOCK) -> np.ndarray:
    """A batch mixing the distribution classes the workloads produce."""
    rng = np.random.default_rng(seed)

    def one(_i):
        kind = rng.integers(0, 6)
        if kind == 0:
            return np.zeros(32, np.uint32)
        if kind == 1:  # narrow ints
            return rng.integers(0, 120, 32).astype(np.uint32)
        if kind == 2:  # low-dynamic-range 8-byte values
            base = rng.integers(0, 1 << 50, dtype=np.uint64)
            v = base + rng.integers(0, 100, 16).astype(np.uint64)
            w = np.empty(32, np.uint32)
            w[0::2] = (v & 0xFFFFFFFF).astype(np.uint32)
            w[1::2] = (v >> 32).astype(np.uint32)
            return w
        if kind == 3:  # pointer-like (C-Pack)
            bases = (rng.integers(0, 1 << 32, 4, dtype=np.int64) & 0xFFFFFF00).astype(
                np.uint32
            )
            return bases[rng.integers(0, 4, 32)] | rng.integers(0, 256, 32).astype(
                np.uint32
            )
        if kind == 4:  # repeated bytes
            b = rng.integers(0, 256, 32).astype(np.uint32)
            return b | (b << 8) | (b << 16) | (b << 24)
        return rng.integers(0, 1 << 32, 32, dtype=np.int64).astype(np.uint32)

    return lines(n, one)


@pytest.mark.parametrize("algo", ALGOS)
def test_kernel_matches_ref_on_patterns(algo):
    for seed in range(8):
        batch = pattern_batch(seed)
        ke, ks = KERNEL_FNS[algo](batch)
        re_, rs = REF_FNS[algo](batch)
        np.testing.assert_array_equal(np.asarray(ke), np.asarray(re_), err_msg=f"{algo} enc seed={seed}")
        np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs), err_msg=f"{algo} size seed={seed}")


@pytest.mark.parametrize("algo", ALGOS)
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_matches_ref_random(algo, seed):
    rng = np.random.default_rng(seed)
    batch = rng.integers(0, 1 << 32, (BLOCK, 32), dtype=np.int64).astype(np.uint32)
    ke, ks = KERNEL_FNS[algo](batch)
    re_, rs = REF_FNS[algo](batch)
    np.testing.assert_array_equal(np.asarray(ke), np.asarray(re_))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


@pytest.mark.parametrize("algo", ALGOS)
@settings(max_examples=10, deadline=None)
@given(
    blocks=st.integers(1, 4),
    fill=st.sampled_from(["zeros", "narrow", "random"]),
)
def test_kernel_shape_sweep(algo, blocks, fill):
    n = BLOCK * blocks
    rng = np.random.default_rng(n)
    if fill == "zeros":
        batch = np.zeros((n, 32), np.uint32)
    elif fill == "narrow":
        batch = rng.integers(0, 50, (n, 32)).astype(np.uint32)
    else:
        batch = rng.integers(0, 1 << 32, (n, 32), dtype=np.int64).astype(np.uint32)
    ke, ks = KERNEL_FNS[algo](batch)
    re_, rs = REF_FNS[algo](batch)
    assert np.asarray(ke).shape == (n,)
    np.testing.assert_array_equal(np.asarray(ke), np.asarray(re_))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


def test_known_verdicts():
    """Hand-checked verdicts pinning the byte-exact spec (mirrors the Rust
    unit tests so a drift on either side fails loudly)."""
    zeros = np.zeros((BLOCK, 32), np.uint32)
    e, s = KERNEL_FNS["bdi"](zeros)
    assert int(e[0]) == 0 and int(s[0]) == 1
    e, s = KERNEL_FNS["fpc"](zeros)
    assert int(e[0]) == 4 and int(s[0]) == 5  # 4 zero segments, hdr+encs
    e, s = KERNEL_FNS["cpack"](zeros)
    assert int(e[0]) == 0 and int(s[0]) == 49

    # The paper's Fig. 6 PVC line: 8-byte base + 1-byte deltas + zero values.
    base = 0x8001D000
    w = np.zeros(32, np.uint32)
    for i in range(16):
        if i % 4 == 0:
            w[2 * i] = base + i
        elif i % 4 == 2:
            w[2 * i] = base + 2 * i
    batch = np.tile(w, (BLOCK, 1)).astype(np.uint32)
    e, s = KERNEL_FNS["bdi"](batch)
    assert int(e[0]) == 2, "base8-delta1"
    assert int(s[0]) == 27  # 1 meta + 2 mask + 8 base + 16 deltas

    # Narrow u32s (< 128): BDI base4-d1 (41B), FPC sign-ext-1 (37B).
    narrow = np.tile(np.arange(1, 33, dtype=np.uint32), (BLOCK, 1))
    e, s = KERNEL_FNS["bdi"](narrow)
    assert int(e[0]) == 5 and int(s[0]) == 41
    e, s = KERNEL_FNS["fpc"](narrow)
    assert int(e[0]) == 4 and int(s[0]) == 37

    # 5 distinct pointer groups: C-Pack must fail the line.
    groups = np.array([0x8001D000, 0x80020000, 0x90001000, 0xA0000000, 0xB0000000], np.uint32)
    five = np.tile(groups[np.arange(32) % 5], (BLOCK, 1))
    e, s = KERNEL_FNS["cpack"](five)
    assert int(e[0]) == 0xFF and int(s[0]) == 129


def test_best_of_all_never_worse():
    from compile.model import analyze_best

    batch = pattern_batch(123)
    _, bs = analyze_best(batch)
    for algo in ALGOS:
        _, s = KERNEL_FNS[algo](batch)
        assert np.all(np.asarray(bs) <= np.asarray(s)), algo
