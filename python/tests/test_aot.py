"""AOT export sanity: the lowered HLO text parses back and the exported
module agrees with direct execution."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import export_all, to_hlo_text
from compile.kernels import BATCH
from compile.model import MODEL_FNS


def test_export_writes_all_artifacts():
    with tempfile.TemporaryDirectory() as d:
        written = export_all(d, batch=BATCH)
        assert set(written) == {"bdi", "fpc", "cpack", "best"}
        for path in written.values():
            text = open(path).read()
            assert text.startswith("HloModule"), path[:60]
            # 64-bit-id protos are the failure mode the text format avoids;
            # text must contain the entry computation.
            assert "ENTRY" in text


def test_jit_matches_eager_and_text_is_parseable():
    """The jitted (exported) graph must match eager execution; the text
    artifact must be structurally valid HLO. The authoritative compile-and-
    execute roundtrip of the text runs on the Rust side
    (rust/tests/integration_pjrt.rs) through the same PJRT CPU client the
    simulator uses — modern jaxlib exposes no HLO-text parse API."""
    rng = np.random.default_rng(7)
    batch = rng.integers(0, 1 << 32, (BATCH, 32), dtype=np.int64).astype(np.uint32)
    for name, fn in MODEL_FNS.items():
        e1, s1 = jax.jit(fn)(batch)
        e2, s2 = fn(batch)
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2), err_msg=name)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2), err_msg=name)
    spec = jax.ShapeDtypeStruct((BATCH, 32), jnp.uint32)
    text = to_hlo_text(jax.jit(MODEL_FNS["bdi"]).lower(spec))
    assert text.startswith("HloModule")
    assert "u32[256,32]" in text.replace(" ", "")


def test_export_is_deterministic():
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        w1 = export_all(d1, batch=64)
        w2 = export_all(d2, batch=64)
        for k in w1:
            assert open(w1[k]).read() == open(w2[k]).read(), k


def test_makefile_stamp_semantics():
    """`make artifacts` must be a no-op when inputs are unchanged — the
    stamp file dependency list covers the kernel/model/aot sources."""
    mk = open(os.path.join(os.path.dirname(__file__), "..", "..", "Makefile")).read()
    assert "python/compile/aot.py" in mk
    assert "kernels/*.py" in mk
