"""Pallas kernel: segmented-FPC compression analysis.

The tile is reshaped into `(lines, 4 segments, 8 words)`; each segment's
pattern test is a lane-axis reduction, mirroring the per-segment uniform
encoding the paper introduces to parallelize FPC across SIMT lanes
(Algorithms 3–4).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    FPC_ENC_UNCOMPRESSED,
    FPC_N_SEGMENTS,
    FPC_SEGMENT_WORDS,
    LINE_BYTES,
)


def _kernel(words_ref, enc_ref, size_ref):
    words = words_ref[...]
    n = words.shape[0]
    seg = words.reshape(n, FPC_N_SEGMENTS, FPC_SEGMENT_WORDS)
    s = seg.astype(jnp.int32)
    zero = jnp.all(seg == 0, axis=2)
    se1 = jnp.all((s >= -128) & (s <= 127), axis=2)
    b = seg & jnp.uint32(0xFF)
    repb = jnp.all(seg == b * jnp.uint32(0x01010101), axis=2)
    se2 = jnp.all((s >= -32768) & (s <= 32767), axis=2)
    bpw = jnp.where(zero, 0, jnp.where(se1, 1, jnp.where(repb, 1, jnp.where(se2, 2, 4))))
    compressed_seg = zero | se1 | repb | se2
    size = (1 + FPC_N_SEGMENTS + FPC_SEGMENT_WORDS * jnp.sum(bpw, axis=1)).astype(jnp.int32)
    n_comp = jnp.sum(compressed_seg.astype(jnp.int32), axis=1)
    passthrough = size >= LINE_BYTES
    enc_ref[...] = jnp.where(passthrough, FPC_ENC_UNCOMPRESSED, n_comp).astype(jnp.int32)
    size_ref[...] = jnp.where(passthrough, 1 + LINE_BYTES, size).astype(jnp.int32)


def fpc_pallas(words, block: int = 64):
    """Analyze `uint32[N, 32]` lines; N must be a multiple of `block`."""
    n = words.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, words.shape[1]), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=True,
    )(words)
