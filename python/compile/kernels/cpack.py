"""Pallas kernel: restricted C-Pack compression analysis.

C-Pack's dictionary build is inherently serial over the 32 words of a line
(Algorithm 6), so the kernel runs a `fori_loop` over word positions while
staying fully vectorized across the lines of the tile — the same
"serial in words, parallel in lanes" shape the paper's assist warp has
(one lane per line here instead of one lane per word, the natural VPU
transposition).
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .ref import (
    CPACK_DICT,
    CPACK_ENC_UNCOMPRESSED,
    LINE_BYTES,
    WORDS_PER_LINE,
    cpack_compressed_size,
)


def _kernel(words_ref, enc_ref, size_ref):
    words = words_ref[...]
    n = words.shape[0]
    lane = jnp.arange(CPACK_DICT)[None, :]

    def step(i, carry):
        dict_vals, dict_len, fail = carry
        w = words[:, i]
        upper = w & jnp.uint32(0xFFFFFF00)
        is_zero = w == 0
        is_zext = (upper == 0) & ~is_zero
        valid = lane < dict_len[:, None]
        full = jnp.any((dict_vals == w[:, None]) & valid, axis=1)
        partial = jnp.any(
            ((dict_vals & jnp.uint32(0xFFFFFF00)) == upper[:, None]) & valid, axis=1
        )
        matched = is_zero | is_zext | full | partial
        need_new = ~matched
        overflow = need_new & (dict_len >= CPACK_DICT)
        append = need_new & ~overflow
        slot = jnp.clip(dict_len, 0, CPACK_DICT - 1)
        one_hot = lane == slot[:, None]
        dict_vals = jnp.where(append[:, None] & one_hot, w[:, None], dict_vals)
        dict_len = dict_len + append.astype(jnp.int32)
        return dict_vals, dict_len, fail | overflow

    init = (
        jnp.zeros((n, CPACK_DICT), jnp.uint32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), bool),
    )
    _, dict_len, fail = lax.fori_loop(0, WORDS_PER_LINE, step, init)
    enc_ref[...] = jnp.where(fail, CPACK_ENC_UNCOMPRESSED, dict_len).astype(jnp.int32)
    size_ref[...] = jnp.where(fail, 1 + LINE_BYTES, cpack_compressed_size(dict_len)).astype(
        jnp.int32
    )


def cpack_pallas(words, block: int = 64):
    """Analyze `uint32[N, 32]` lines; N must be a multiple of `block`."""
    n = words.shape[0]
    return pl.pallas_call(
        _kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, words.shape[1]), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=True,
    )(words)
