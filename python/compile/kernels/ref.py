"""Pure-jnp correctness oracles for the compression-analysis kernels.

Each function maps a batch of 128-byte cache lines (as ``uint32[N, 32]``
little-endian words) to ``(encoding int32[N], size_bytes int32[N])`` and is
the bit-exact specification the Pallas kernels (bdi.py / fpc.py / cpack.py)
and the Rust `NativeOracle` must agree with (see
rust/tests/integration_pjrt.rs).

Semantics mirror rust/src/compress/{bdi,fpc,cpack}.rs exactly, including
encoding preference order, tie-breaking, and metadata byte counts.
"""

import jax.numpy as jnp
from jax import lax

WORDS_PER_LINE = 32
LINE_BYTES = 128

# --- BDI constants (rust/src/compress/bdi.rs) ---
BDI_ENC_ZEROS = 0
BDI_ENC_REPEAT = 1
BDI_ENC_UNCOMPRESSED = 15
# (enc, base_size, delta_size) in the exact preference order the Rust
# compressor tries them (stable sort of BASE_DELTA_ENCODINGS by size).
BDI_GEOMETRIES = (
    (2, 8, 1),  # base8-d1,  27 B
    (5, 4, 1),  # base4-d1,  41 B
    (3, 8, 2),  # base8-d2,  43 B
    (6, 4, 2),  # base4-d2,  73 B
    (7, 2, 1),  # base2-d1,  75 B
    (4, 8, 4),  # base8-d4,  75 B
)


def bdi_encoded_size(base_size: int, delta_size: int) -> int:
    n = LINE_BYTES // base_size
    return 1 + n // 8 + base_size + n * delta_size


def _as_values(words, base_size: int):
    """View u32[N,32] as unsigned values of `base_size` bytes → u64[N, n]."""
    w = words.astype(jnp.uint64)
    if base_size == 4:
        return w
    if base_size == 8:
        lo = w[:, 0::2]
        hi = w[:, 1::2]
        return lo | (hi << jnp.uint64(32))
    if base_size == 2:
        lo = w & jnp.uint64(0xFFFF)
        hi = (w >> jnp.uint64(16)) & jnp.uint64(0xFFFF)
        # interleave: value i*2 = lo word, i*2+1 = hi word
        return jnp.stack([lo, hi], axis=-1).reshape(w.shape[0], -1)
    raise ValueError(base_size)


def _first_nonzero(v):
    """Per row: first non-zero value (0 if all zero) — the BDI base."""
    nz = v != 0
    idx = jnp.argmax(nz, axis=1)
    return jnp.take_along_axis(v, idx[:, None], axis=1)[:, 0]


def _fits(d, delta_size: int):
    """Does wrapped difference `d` (u64) fit a signed `delta_size`-byte int?"""
    m = jnp.uint64(1 << (8 * delta_size - 1))
    return (d + m) < (m + m)  # u64 wrap-around makes this the signed check


def bdi_ref(words):
    """BDI analysis: (encoding, size_bytes) per line."""
    n_lines = words.shape[0]
    enc = jnp.full((n_lines,), BDI_ENC_UNCOMPRESSED, jnp.int32)
    size = jnp.full((n_lines,), 1 + LINE_BYTES, jnp.int32)
    decided = jnp.zeros((n_lines,), bool)

    # Geometries, tried in preference order (worst first so better ones
    # overwrite — we instead guard with `decided`).
    for g_enc, base_size, delta_size in reversed(BDI_GEOMETRIES):
        v = _as_values(words, base_size)
        base = _first_nonzero(v)[:, None]
        ok = jnp.all(_fits(v - base, delta_size) | _fits(v, delta_size), axis=1)
        enc = jnp.where(ok, g_enc, enc)
        size = jnp.where(ok, bdi_encoded_size(base_size, delta_size), size)

    del decided
    # Repeated 8-byte value (higher priority than any geometry).
    v8 = _as_values(words, 8)
    rep = jnp.all(v8 == v8[:, :1], axis=1)
    enc = jnp.where(rep, BDI_ENC_REPEAT, enc)
    size = jnp.where(rep, 9, size)
    # All zeros (highest priority).
    zeros = jnp.all(words == 0, axis=1)
    enc = jnp.where(zeros, BDI_ENC_ZEROS, enc)
    size = jnp.where(zeros, 1, size)
    return enc.astype(jnp.int32), size.astype(jnp.int32)


# --- FPC (rust/src/compress/fpc.rs, segmented variant) ---
FPC_SEGMENT_WORDS = 8
FPC_N_SEGMENTS = WORDS_PER_LINE // FPC_SEGMENT_WORDS
FPC_ENC_UNCOMPRESSED = 0xFF


def fpc_ref(words):
    """Segmented-FPC analysis: (encoding, size_bytes) per line.

    encoding = number of compressed segments (the AWS subroutine selector
    the Rust side uses), or 0xFF for a passthrough line.
    """
    n_lines = words.shape[0]
    seg = words.reshape(n_lines, FPC_N_SEGMENTS, FPC_SEGMENT_WORDS)
    s = seg.astype(jnp.int32)
    zero = jnp.all(seg == 0, axis=2)
    se1 = jnp.all((s >= -128) & (s <= 127), axis=2)
    b = seg & 0xFF
    repb = jnp.all(seg == b * 0x01010101, axis=2)
    se2 = jnp.all((s >= -32768) & (s <= 32767), axis=2)
    # Pattern choice in CANDIDATES order: Zero, SignExt1, RepByte, SignExt2,
    # Uncompressed → payload bytes/word 0,1,1,2,4.
    bpw = jnp.where(
        zero, 0, jnp.where(se1, 1, jnp.where(repb, 1, jnp.where(se2, 2, 4)))
    )
    compressed_seg = zero | se1 | repb | se2
    size = 1 + FPC_N_SEGMENTS + FPC_SEGMENT_WORDS * jnp.sum(bpw, axis=1)
    n_comp = jnp.sum(compressed_seg.astype(jnp.int32), axis=1)
    passthrough = size >= LINE_BYTES
    enc = jnp.where(passthrough, FPC_ENC_UNCOMPRESSED, n_comp)
    size = jnp.where(passthrough, 1 + LINE_BYTES, size)
    return enc.astype(jnp.int32), size.astype(jnp.int32)


# --- C-Pack (rust/src/compress/cpack.rs, restricted variant) ---
CPACK_DICT = 4
CPACK_ENC_UNCOMPRESSED = 0xFF


def cpack_compressed_size(dict_used):
    # [hdr][codes 4-bit x32][dict 4B x used][payload 1B x32] = 49 + 4*used
    return 1 + WORDS_PER_LINE // 2 + dict_used * 4 + WORDS_PER_LINE


def cpack_ref(words):
    """Restricted C-Pack analysis: (encoding, size_bytes) per line.

    The dictionary build is serial (Algorithm 6): scan the 32 words,
    adding a new dictionary entry whenever a word matches no pattern and
    no existing entry; a 5th needed entry fails the line.
    """
    n_lines = words.shape[0]

    def step(carry, w):
        dict_vals, dict_len, fail = carry  # (N,4) u32, (N,) i32, (N,) bool
        upper = w & jnp.uint32(0xFFFFFF00)
        is_zero = w == 0
        is_zext = (upper == 0) & ~is_zero
        full = (dict_vals == w[:, None]) & (
            jnp.arange(CPACK_DICT)[None, :] < dict_len[:, None]
        )
        partial = ((dict_vals & jnp.uint32(0xFFFFFF00)) == upper[:, None]) & (
            jnp.arange(CPACK_DICT)[None, :] < dict_len[:, None]
        )
        matched = is_zero | is_zext | jnp.any(full, axis=1) | jnp.any(partial, axis=1)
        need_new = ~matched
        overflow = need_new & (dict_len >= CPACK_DICT)
        # Append w where a new entry is needed and there is room.
        slot = jnp.clip(dict_len, 0, CPACK_DICT - 1)
        append = need_new & ~overflow
        one_hot = jnp.arange(CPACK_DICT)[None, :] == slot[:, None]
        dict_vals = jnp.where(append[:, None] & one_hot, w[:, None], dict_vals)
        dict_len = dict_len + append.astype(jnp.int32)
        fail = fail | overflow
        return (dict_vals, dict_len, fail), None

    init = (
        jnp.zeros((n_lines, CPACK_DICT), jnp.uint32),
        jnp.zeros((n_lines,), jnp.int32),
        jnp.zeros((n_lines,), bool),
    )
    (dict_vals, dict_len, fail), _ = lax.scan(step, init, jnp.swapaxes(words, 0, 1))
    del dict_vals
    enc = jnp.where(fail, CPACK_ENC_UNCOMPRESSED, dict_len)
    size = jnp.where(fail, 1 + LINE_BYTES, cpack_compressed_size(dict_len))
    return enc.astype(jnp.int32), size.astype(jnp.int32)


def best_ref(words):
    """Per-line best of the three algorithms (paper's CABA-BestOfAll):
    smallest size wins; ties resolve BDI > FPC > C-Pack (the Rust order)."""
    be, bs = bdi_ref(words)
    fe, fs = fpc_ref(words)
    ce, cs = cpack_ref(words)
    enc, size = be, bs
    better = fs < size
    enc = jnp.where(better, fe, enc)
    size = jnp.where(better, fs, size)
    better = cs < size
    enc = jnp.where(better, ce, enc)
    size = jnp.where(better, cs, size)
    return enc.astype(jnp.int32), size.astype(jnp.int32)


REF_FNS = {"bdi": bdi_ref, "fpc": fpc_ref, "cpack": cpack_ref, "best": best_ref}
