"""Pallas kernel: BDI compression analysis.

One grid step analyzes a `(BLOCK, 32)`-word tile of cache lines held in
VMEM. The per-line reduction over lanes (`jnp.all`) is the VPU analogue of
the paper's warp-wide predicate AND (the "global predicate register" of
§5.1.2); the geometry cascade mirrors Algorithm 2's encoding loop.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BDI_GEOMETRIES, BDI_ENC_REPEAT, BDI_ENC_ZEROS, BDI_ENC_UNCOMPRESSED, LINE_BYTES, bdi_encoded_size


def _values(words, base_size):
    w = words.astype(jnp.uint64)
    if base_size == 4:
        return w
    if base_size == 8:
        return w[:, 0::2] | (w[:, 1::2] << jnp.uint64(32))
    lo = w & jnp.uint64(0xFFFF)
    hi = (w >> jnp.uint64(16)) & jnp.uint64(0xFFFF)
    return jnp.stack([lo, hi], axis=-1).reshape(w.shape[0], -1)


def _kernel(words_ref, enc_ref, size_ref):
    words = words_ref[...]
    n = words.shape[0]
    enc = jnp.full((n,), BDI_ENC_UNCOMPRESSED, jnp.int32)
    size = jnp.full((n,), 1 + LINE_BYTES, jnp.int32)

    # Geometry cascade, worst-preference first so better ones overwrite.
    for g_enc, base_size, delta_size in reversed(BDI_GEOMETRIES):
        v = _values(words, base_size)
        nz = v != 0
        first = jnp.argmax(nz, axis=1)
        base = jnp.take_along_axis(v, first[:, None], axis=1)
        m = jnp.uint64(1 << (8 * delta_size - 1))
        two_m = m + m
        fits_base = (v - base + m) < two_m  # u64 wrap = signed range check
        fits_zero = (v + m) < two_m
        ok = jnp.all(fits_base | fits_zero, axis=1)
        enc = jnp.where(ok, g_enc, enc)
        size = jnp.where(ok, bdi_encoded_size(base_size, delta_size), size)

    v8 = _values(words, 8)
    rep = jnp.all(v8 == v8[:, :1], axis=1)
    enc = jnp.where(rep, BDI_ENC_REPEAT, enc)
    size = jnp.where(rep, 9, size)
    zeros = jnp.all(words == 0, axis=1)
    enc = jnp.where(zeros, BDI_ENC_ZEROS, enc)
    size = jnp.where(zeros, 1, size)

    enc_ref[...] = enc
    size_ref[...] = size


def bdi_pallas(words, block: int = 64):
    """Analyze `uint32[N, 32]` lines; N must be a multiple of `block`."""
    n = words.shape[0]
    grid = (n // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block, words.shape[1]), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ),
        interpret=True,
    )(words)
