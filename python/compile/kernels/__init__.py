"""L1 Pallas kernels: compression analysis for BDI / FPC / C-Pack.

`interpret=True` everywhere — the CPU PJRT backend cannot execute Mosaic
custom-calls; interpret mode lowers the kernels to plain HLO that both the
build-time pytest and the Rust runtime can run (see DESIGN.md
§Hardware-Adaptation for the TPU mapping rationale).

NOTE: BDI needs uint64 arithmetic — callers must enable x64
(`jax.config.update("jax_enable_x64", True)`) before tracing.
"""

from .bdi import bdi_pallas
from .cpack import cpack_pallas
from .fpc import fpc_pallas

# Default batch/block geometry shared with aot.py and the Rust runtime
# (rust/src/runtime/mod.rs: BATCH).
BATCH = 256
BLOCK = 64

KERNEL_FNS = {
    "bdi": bdi_pallas,
    "fpc": fpc_pallas,
    "cpack": cpack_pallas,
}
