"""AOT export: lower the L2 model (with its L1 Pallas kernels) to HLO text.

HLO *text*, not ``lowered.compiler_ir("hlo").serialize()`` — jax ≥ 0.5 emits
protos with 64-bit instruction ids that the image's xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and DESIGN.md).

Usage (via `make artifacts`):
    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # BDI needs uint64 arithmetic

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from .kernels import BATCH  # noqa: E402
from .model import MODEL_FNS  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str, batch: int = BATCH) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    spec = jax.ShapeDtypeStruct((batch, 32), jnp.uint32)
    written = {}
    for name, fn in MODEL_FNS.items():
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written[name] = path
        print(f"wrote {path} ({len(text)} chars, batch={batch})")
    return written


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--batch", type=int, default=BATCH)
    args = p.parse_args()
    export_all(args.out_dir, args.batch)


if __name__ == "__main__":
    main()
