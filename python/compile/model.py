"""L2 model: the assist-warp compute expressed as a JAX graph.

`analyze_<algo>(words)` maps a batch of cache lines (`uint32[N, 32]`) to
`(encoding int32[N], size_bytes int32[N])` by calling the L1 Pallas
kernels; `analyze_best` fuses all three and reduces per line — the
CABA-BestOfAll selection of §7.3 as one dataflow graph.

These are the functions `aot.py` lowers to the HLO artifacts the Rust
runtime executes; Python never runs at simulation time.
"""

import jax.numpy as jnp

from .kernels import bdi_pallas, cpack_pallas, fpc_pallas


def analyze_bdi(words):
    return bdi_pallas(words)


def analyze_fpc(words):
    return fpc_pallas(words)


def analyze_cpack(words):
    return cpack_pallas(words)


def analyze_best(words):
    """Per-line best-of-{BDI, FPC, C-Pack}; ties prefer BDI then FPC then
    C-Pack (matching `caba::compress::compress(Algo::BestOfAll, ..)`)."""
    be, bs = analyze_bdi(words)
    fe, fs = analyze_fpc(words)
    ce, cs = analyze_cpack(words)
    enc, size = be, bs
    better = fs < size
    enc = jnp.where(better, fe, enc)
    size = jnp.where(better, fs, size)
    better = cs < size
    enc = jnp.where(better, ce, enc)
    size = jnp.where(better, cs, size)
    return enc, size


MODEL_FNS = {
    "bdi": analyze_bdi,
    "fpc": analyze_fpc,
    "cpack": analyze_cpack,
    "best": analyze_best,
}
